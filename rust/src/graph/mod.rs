//! Task-graph substrate: the DAG model of §2.2.
//!
//! A directed acyclic graph `(V, E, t, w)` where nodes are network layers
//! (tasks), `t(v)` is the per-node WCET in cycles, and `w(e)` is the
//! communication latency paid when the two endpoints of `e` execute on
//! different cores. All times are integer cycles (`u64`): the paper samples
//! integer weights from U[1,10] and OTAWA bounds are integral cycle counts.

mod levels;
mod single_sink;

pub use levels::{critical_nodes, critical_path_len, static_levels, top_levels};
pub use single_sink::ensure_single_sink;

use std::collections::VecDeque;

/// Index of a node in a [`Dag`].
pub type NodeId = usize;

/// Cycle count (WCET or communication latency).
pub type Cycles = u64;

/// A directed acyclic task graph `(V, E, t, w)` (§2.2).
///
/// Edges are stored in both directions (children and parents) for O(1)
/// neighbourhood queries, which every scheduler in `crate::sched` relies on.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    names: Vec<String>,
    wcet: Vec<Cycles>,
    /// `children[u]` = outgoing edges `(v, w(u→v))`.
    children: Vec<Vec<(NodeId, Cycles)>>,
    /// `parents[v]` = incoming edges `(u, w(u→v))`.
    parents: Vec<Vec<(NodeId, Cycles)>>,
}

impl Dag {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node with the given display name and WCET; returns its id.
    pub fn add_node(&mut self, name: impl Into<String>, wcet: Cycles) -> NodeId {
        let id = self.names.len();
        self.names.push(name.into());
        self.wcet.push(wcet);
        self.children.push(Vec::new());
        self.parents.push(Vec::new());
        id
    }

    /// Add edge `u → v` with communication latency `w`.
    ///
    /// Panics if the edge would duplicate an existing one or if `u == v`.
    pub fn add_edge(&mut self, u: NodeId, v: NodeId, w: Cycles) {
        assert_ne!(u, v, "self-loop");
        assert!(
            !self.children[u].iter().any(|&(c, _)| c == v),
            "duplicate edge {u}->{v}"
        );
        self.children[u].push((v, w));
        self.parents[v].push((u, w));
    }

    /// Number of nodes `|V|`.
    pub fn n(&self) -> usize {
        self.names.len()
    }

    /// Number of edges `|E|`.
    pub fn edge_count(&self) -> usize {
        self.children.iter().map(Vec::len).sum()
    }

    /// WCET `t(v)`.
    pub fn wcet(&self, v: NodeId) -> Cycles {
        self.wcet[v]
    }

    /// Override `t(v)` (used when re-annotating a network DAG with a
    /// different cost model).
    pub fn set_wcet(&mut self, v: NodeId, t: Cycles) {
        self.wcet[v] = t;
    }

    /// Display name of `v`.
    pub fn name(&self, v: NodeId) -> &str {
        &self.names[v]
    }

    /// Outgoing edges of `u` as `(child, w)`.
    pub fn children(&self, u: NodeId) -> &[(NodeId, Cycles)] {
        &self.children[u]
    }

    /// Incoming edges of `v` as `(parent, w)`.
    pub fn parents(&self, v: NodeId) -> &[(NodeId, Cycles)] {
        &self.parents[v]
    }

    /// Latency of edge `u → v`, if present.
    pub fn edge_weight(&self, u: NodeId, v: NodeId) -> Option<Cycles> {
        self.children[u].iter().find(|&&(c, _)| c == v).map(|&(_, w)| w)
    }

    /// All edges `(u, v, w)`.
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId, Cycles)> + '_ {
        self.children
            .iter()
            .enumerate()
            .flat_map(|(u, cs)| cs.iter().map(move |&(v, w)| (u, v, w)))
    }

    /// Nodes with no parents.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.n()).filter(|&v| self.parents[v].is_empty()).collect()
    }

    /// Nodes with no children.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.n()).filter(|&v| self.children[v].is_empty()).collect()
    }

    /// The unique sink, if the graph has exactly one.
    pub fn single_sink(&self) -> Option<NodeId> {
        let s = self.sinks();
        (s.len() == 1).then(|| s[0])
    }

    /// Sum of all node WCETs: the single-core makespan (no idle time is ever
    /// needed on one core) and the "theoretical maximum" of constraint (13).
    pub fn total_wcet(&self) -> Cycles {
        self.wcet.iter().sum()
    }

    /// Kahn topological order. Panics if the graph has a cycle (the
    /// constructors in `daggen`/`nn` only build acyclic graphs; a cycle here
    /// is a programming error).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg: Vec<usize> = (0..self.n()).map(|v| self.parents[v].len()).collect();
        let mut queue: VecDeque<NodeId> =
            (0..self.n()).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(self.n());
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &(v, _) in &self.children[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        assert_eq!(order.len(), self.n(), "graph has a cycle");
        order
    }

    /// True if the edge relation is acyclic (checked without panicking).
    pub fn is_acyclic(&self) -> bool {
        let mut indeg: Vec<usize> = (0..self.n()).map(|v| self.parents[v].len()).collect();
        let mut queue: VecDeque<NodeId> =
            (0..self.n()).filter(|&v| indeg[v] == 0).collect();
        let mut seen = 0;
        while let Some(u) = queue.pop_front() {
            seen += 1;
            for &(v, _) in &self.children[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        seen == self.n()
    }

    /// Maximum width of the DAG: the size of the largest antichain, i.e. the
    /// paper's "maximal parallelization value" (§4.2 Observation 1) — the
    /// number of cores beyond which speedup plateaus.
    ///
    /// Computed exactly via Dilworth's theorem: width = |V| − (maximum
    /// matching in the bipartite graph of the transitive closure). The
    /// closure is built on u64-word bitset rows — one reverse-topological
    /// pass OR-ing child rows, O(|E|·n/64) instead of the old
    /// `Vec<Vec<bool>>` construction's O(n³) bit-at-a-time copies — and the
    /// augmenting-path matching walks set bits word by word.
    pub fn width(&self) -> usize {
        let n = self.n();
        if n == 0 {
            return 0;
        }
        let words = (n + 63) / 64;
        // reach[u*words ..][..] = bitset of nodes reachable from u.
        let mut reach = vec![0u64; n * words];
        for u in self.topo_order().into_iter().rev() {
            for &(v, _) in &self.children[u] {
                reach[u * words + v / 64] |= 1 << (v % 64);
                for w in 0..words {
                    let child_row = reach[v * words + w];
                    reach[u * words + w] |= child_row;
                }
            }
        }
        // Hopcroft–Karp is overkill: simple Hungarian augmenting paths.
        fn try_assign(
            u: usize,
            reach: &[u64],
            words: usize,
            visited: &mut [bool],
            match_r: &mut [Option<usize>],
        ) -> bool {
            for w in 0..words {
                let mut bits = reach[u * words + w];
                while bits != 0 {
                    let v = w * 64 + bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    if !visited[v] {
                        visited[v] = true;
                        if match_r[v].is_none()
                            || try_assign(match_r[v].unwrap(), reach, words, visited, match_r)
                        {
                            match_r[v] = Some(u);
                            return true;
                        }
                    }
                }
            }
            false
        }
        let mut match_r: Vec<Option<usize>> = vec![None; n];
        let mut matched = 0;
        for u in 0..n {
            let mut visited = vec![false; n];
            if try_assign(u, &reach, words, &mut visited, &mut match_r) {
                matched += 1;
            }
        }
        n - matched
    }

    /// Edge density as defined by Eq. (14): `|E| / (|V|(|V|−1)/2)`.
    /// Graphs with fewer than two nodes have no possible edge; their
    /// density is defined as 0 (the naive formula divides by zero).
    pub fn density(&self) -> f64 {
        let n = self.n();
        if n <= 1 {
            return 0.0;
        }
        let pairs = (n * (n - 1) / 2) as f64;
        self.edge_count() as f64 / pairs
    }

    /// Graphviz DOT rendering (node label = `name\nt(v)`, edge label = `w`).
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph dag {\n  rankdir=TB;\n");
        for v in 0..self.n() {
            s.push_str(&format!(
                "  n{} [label=\"{}\\nt={}\"];\n",
                v,
                self.names[v],
                self.wcet[v]
            ));
        }
        for (u, v, w) in self.edges() {
            s.push_str(&format!("  n{u} -> n{v} [label=\"{w}\"];\n"));
        }
        s.push_str("}\n");
        s
    }
}

/// The 9-node example DAG of Fig. 3 (black part), used throughout the
/// paper's worked examples (Figs. 4–6). Node ids are `label − 1`.
///
/// WCETs (underlined in the figure) and edge latencies (gray) are chosen to
/// reproduce the published Gantt charts exactly:
/// * ISH on 2 cores schedules 1,6 on P1, 5 on P2, inserts node 2 into the
///   idle slot [5,6) created while waiting for node 5's data (Fig. 4);
/// * DSH duplicates node 1 onto P2 to remove the 1→5 communication (Fig. 5).
pub fn paper_example_dag() -> Dag {
    let mut g = Dag::new();
    // label:        1  2  3  4  5  6  7  8  9
    let t = [1u64, 1, 2, 1, 2, 3, 3, 2, 1];
    let ids: Vec<NodeId> = (0..9)
        .map(|i| g.add_node(format!("{}", i + 1), t[i]))
        .collect();
    // Fan-out from node 1 to five parallel branches (width 5, §4.2 Obs. 1
    // names this graph's maximal parallelism as 5).
    g.add_edge(ids[0], ids[1], 1); // 1→2
    g.add_edge(ids[0], ids[2], 2); // 1→3
    g.add_edge(ids[0], ids[3], 1); // 1→4
    g.add_edge(ids[0], ids[4], 1); // 1→5  (w=1: P2 can start node 5 at 2)
    g.add_edge(ids[0], ids[5], 1); // 1→6
    g.add_edge(ids[4], ids[6], 2); // 5→7  (w=2: comm delay seen in Fig. 4)
    g.add_edge(ids[5], ids[6], 1); // 6→7
    g.add_edge(ids[1], ids[7], 1); // 2→8
    g.add_edge(ids[2], ids[7], 1); // 3→8
    g.add_edge(ids[3], ids[8], 1); // 4→9
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let mut g = Dag::new();
        let a = g.add_node("a", 3);
        let b = g.add_node("b", 4);
        g.add_edge(a, b, 2);
        assert_eq!(g.n(), 2);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.wcet(a), 3);
        assert_eq!(g.edge_weight(a, b), Some(2));
        assert_eq!(g.edge_weight(b, a), None);
        assert_eq!(g.children(a), &[(b, 2)]);
        assert_eq!(g.parents(b), &[(a, 2)]);
        assert_eq!(g.sources(), vec![a]);
        assert_eq!(g.sinks(), vec![b]);
        assert_eq!(g.total_wcet(), 7);
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = paper_example_dag();
        let order = g.topo_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.n()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for (u, v, _) in g.edges() {
            assert!(pos[u] < pos[v], "edge {u}->{v} violates topo order");
        }
    }

    #[test]
    fn acyclicity() {
        let g = paper_example_dag();
        assert!(g.is_acyclic());
    }

    #[test]
    fn example_dag_shape() {
        let g = paper_example_dag();
        assert_eq!(g.n(), 9);
        // Fig. 3's graph has several sinks before the one-sink transform.
        assert!(g.sinks().len() > 1);
        // §4.2 Observation 1: maximal parallelism of the Fig. 3 graph is 5.
        assert_eq!(g.width(), 5);
    }

    #[test]
    fn width_of_chain_is_one() {
        let mut g = Dag::new();
        let a = g.add_node("a", 1);
        let b = g.add_node("b", 1);
        let c = g.add_node("c", 1);
        g.add_edge(a, b, 1);
        g.add_edge(b, c, 1);
        assert_eq!(g.width(), 1);
    }

    #[test]
    fn width_of_independent_nodes() {
        let mut g = Dag::new();
        for i in 0..4 {
            g.add_node(format!("{i}"), 1);
        }
        assert_eq!(g.width(), 4);
    }

    #[test]
    fn density_degenerate_graphs_are_zero() {
        let g = Dag::new();
        assert_eq!(g.density(), 0.0, "empty graph");
        let mut g1 = Dag::new();
        g1.add_node("solo", 1);
        assert_eq!(g1.density(), 0.0, "single node");
        assert!(g1.density().is_finite());
    }

    #[test]
    fn width_of_empty_graph_is_zero() {
        assert_eq!(Dag::new().width(), 0);
    }

    #[test]
    fn width_with_many_nodes_crosses_word_boundary() {
        // 70 independent nodes (> one u64 word) plus a chain: the bitset
        // rows must track bits beyond index 63.
        let mut g = Dag::new();
        for i in 0..70 {
            g.add_node(format!("{i}"), 1);
        }
        assert_eq!(g.width(), 70);
        let mut chain = Dag::new();
        let ids: Vec<NodeId> = (0..70).map(|i| chain.add_node(format!("{i}"), 1)).collect();
        for w in ids.windows(2) {
            chain.add_edge(w[0], w[1], 1);
        }
        assert_eq!(chain.width(), 1);
    }

    #[test]
    fn density_formula() {
        let mut g = Dag::new();
        let a = g.add_node("a", 1);
        let b = g.add_node("b", 1);
        let c = g.add_node("c", 1);
        g.add_edge(a, b, 1);
        g.add_edge(a, c, 1);
        // 2 edges / (3·2/2 = 3) = 2/3
        assert!((g.density() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn dot_contains_nodes_and_edges() {
        let g = paper_example_dag();
        let dot = g.to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("n0 -> n1"));
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn duplicate_edge_panics() {
        let mut g = Dag::new();
        let a = g.add_node("a", 1);
        let b = g.add_node("b", 1);
        g.add_edge(a, b, 1);
        g.add_edge(a, b, 2);
    }
}
