//! Cycle-level multi-core platform simulator (the Keystone II substitute).
//!
//! Executes the per-core programs derived from a schedule
//! ([`crate::sched::derive_programs`]) under the **full** §5.2 flag
//! protocol, including the single-buffer back-pressure that makes a
//! Writing operator wait for the reader of the previous message — the
//! effect the paper measures in §5.5 Observation 3 (predicted 46 % segment
//! gain → observed 31 %).
//!
//! The simulator is deterministic given a seed. Two optional effects model
//! the target's measured behaviour (Table 3):
//! * **jitter** — each step's cost is scaled by `U[1, 1+jitter)`, standing
//!   in for cache/DRAM variation on the real board;
//! * **copy contention** — memory-bound copy layers (Input/Split/Concat)
//!   are scaled by a contention factor when several cores are active
//!   (Table 3's Input layer runs 3.4× slower multi-core: all four cores
//!   stream the input simultaneously over one bus).
//!
//! Under a heterogeneous [`Platform`] the replay prices compute per core
//! (`plat.cost`) *and* routes the nominal Write/Read cost through the
//! class × class communication factors (`plat.comm`) — a uniform
//! platform replays byte-identically to no platform at all.
//!
//! Besides the one-shot replay, [`simulate_stream`] replays a
//! K-iteration *inference stream* of a `sched::pipeline` kernel
//! (scheduled starts as release times, one DAG copy per iteration) and
//! measures the steady-state period and the per-channel message
//! high-water mark — the executable cross-check of the pipeline's
//! `II`/buffer-depth claims.

use crate::graph::{Cycles, Dag, NodeId};
use crate::sched::pipeline::{unroll_dag, unroll_platform};
use crate::sched::{derive_programs, CoreStep, Platform, ResolvedPlatform, Schedule};
use crate::util::rng::SplitMix64;
use std::collections::HashMap;

/// Platform configuration (§2.1's UMA multi-core).
#[derive(Debug, Clone)]
pub struct Machine {
    /// Cost of the data-handling part of a Write/Read operator, as a
    /// function of the payload bytes (usually `CostModel::comm_wcet`).
    pub comm_cycles: fn(usize) -> Cycles,
    /// Payload size per producing node (bytes).
    pub payload_bytes: HashMap<NodeId, usize>,
    /// Multiplicative execution-time jitter bound (0.0 = WCET-exact run).
    pub jitter: f64,
    /// Slow-down factor applied to copy-class nodes while >1 core is busy.
    pub copy_contention: f64,
    /// Node ids considered copy-class (memory-bound) for contention.
    pub copy_nodes: Vec<NodeId>,
    /// RNG seed for jitter.
    pub seed: u64,
    /// Buffer slots per channel. 1 = the paper's single-buffer protocol
    /// (§5.2); larger values model the non-blocking-write schemes the
    /// paper lists as future work (a writer only stalls once `capacity`
    /// messages are in flight). See `figures ablation-buffers`.
    pub channel_capacity: usize,
}

impl Machine {
    /// WCET-exact machine: no jitter, no contention, fixed comm cost.
    pub fn exact(comm_cycles: fn(usize) -> Cycles) -> Self {
        Self {
            comm_cycles,
            payload_bytes: HashMap::new(),
            jitter: 0.0,
            copy_contention: 1.0,
            copy_nodes: Vec::new(),
            seed: 0,
            channel_capacity: 1,
        }
    }

    fn payload(&self, node: NodeId) -> usize {
        self.payload_bytes.get(&node).copied().unwrap_or(0)
    }
}

/// One executed step in a core's timeline.
#[derive(Debug, Clone)]
pub struct TimelineEntry {
    pub desc: String,
    pub node: Option<NodeId>,
    pub start: Cycles,
    pub end: Cycles,
    /// Cycles spent spinning on a flag before the operation proper.
    pub wait: Cycles,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub makespan: Cycles,
    pub per_core: Vec<Vec<TimelineEntry>>,
    /// Per node: maximum observed compute duration over instances
    /// (Table 3 reports the highest instance when duplicated).
    pub node_cycles: HashMap<NodeId, Cycles>,
    /// Total cycles all cores spent waiting on flags.
    pub total_wait: Cycles,
    /// Writer-side stalls only (buffer not yet consumed — §5.5 Obs 3);
    /// the rest of `total_wait` is readers waiting on data.
    pub write_wait: Cycles,
}

impl SimReport {
    /// Eq. (15) against a serial baseline.
    pub fn speedup(&self, serial: Cycles) -> f64 {
        serial as f64 / self.makespan as f64
    }
}

/// Simulate a schedule on the machine (uniform cores). Panics on protocol
/// deadlock (which a valid schedule-derived program can't produce — a
/// panic here indicates a scheduler bug, and the tests rely on that).
pub fn simulate(g: &Dag, schedule: &Schedule, machine: &Machine) -> SimReport {
    let plat = ResolvedPlatform::resolve(None, g, schedule.m.max(1));
    simulate_on(g, &plat, schedule, machine)
}

/// Platform-aware simulation: a compute step on core `c` costs
/// `plat.cost(node, c)` (before jitter/contention) instead of the bare
/// WCET, matching what a platform-aware scheduler promised. The machine's
/// `comm_cycles` model prices payload bytes per Write/Read operator, and
/// that nominal cost is then routed through the platform's class × class
/// communication factors (`plat.comm(src, dst, ·)`) — the uniform
/// platform leaves it untouched, byte for byte.
pub fn simulate_on(
    g: &Dag,
    plat: &ResolvedPlatform,
    schedule: &Schedule,
    machine: &Machine,
) -> SimReport {
    run_sim(g, plat, schedule, machine, false).0
}

/// The shared event loop. `honor_starts` selects between the two replay
/// semantics:
///
/// * `false` (the one-shot [`simulate_on`] contract): every step fires as
///   soon as the flag protocol allows (ASAP, work-conserving) — scheduled
///   start times are ignored, so a zero-comm replay can *beat* the
///   schedule's makespan;
/// * `true` (the [`simulate_stream`] contract): a compute step treats its
///   scheduled start as a *release time* (`max(core clock, start)`),
///   which is what makes a pipelined stream admit iterations at exactly
///   the initiation interval instead of racing ahead of it.
///
/// Also returns the high-water mark of in-flight (written, not yet read)
/// messages over all channels — the measured counterpart of
/// `sched::pipeline`'s reported buffer depth.
fn run_sim(
    g: &Dag,
    plat: &ResolvedPlatform,
    schedule: &Schedule,
    machine: &Machine,
    honor_starts: bool,
) -> (SimReport, usize) {
    let programs = derive_programs(g, schedule);
    let m = programs.len();
    let mut pc = vec![0usize; m];
    let mut clock = vec![0u64; m];
    let mut timeline: Vec<Vec<TimelineEntry>> = vec![Vec::new(); m];
    // Channel state: completion times of finished writes/reads, in
    // sequence order (generalizes the single flag to `channel_capacity`
    // in-flight messages).
    #[derive(Default)]
    struct Chan {
        write_done: Vec<Cycles>,
        read_done: Vec<Cycles>,
    }
    let mut chans: HashMap<(usize, usize), Chan> = HashMap::new();
    let cap = machine.channel_capacity.max(1);
    let mut node_cycles: HashMap<NodeId, Cycles> = HashMap::new();
    let mut total_wait = 0u64;
    let mut write_wait = 0u64;
    let mut max_unread = 0usize;
    let mut rng = SplitMix64::new(machine.seed ^ 0x5157);

    let jittered = |rng: &mut SplitMix64, base: Cycles, m_cfg: &Machine| -> Cycles {
        if m_cfg.jitter == 0.0 {
            base
        } else {
            let u = rng.next_f64();
            (base as f64 * (1.0 + m_cfg.jitter * u)).round() as Cycles
        }
    };

    loop {
        // Pick, among runnable steps, the one on the least-advanced core —
        // a deterministic scheduling of the event loop.
        let mut progressed = false;
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by_key(|&c| (clock[c], c));
        for &c in &order {
            if pc[c] >= programs[c].steps.len() {
                continue;
            }
            match &programs[c].steps[pc[c]] {
                CoreStep::Compute { node, start: sched_start, .. } => {
                    let mut cost = jittered(&mut rng, plat.cost(*node, c), machine);
                    // Copy-class contention: any other core still running?
                    let others_busy = (0..m).any(|o| {
                        o != c && pc[o] < programs[o].steps.len()
                    });
                    if others_busy
                        && machine.copy_contention > 1.0
                        && machine.copy_nodes.contains(node)
                    {
                        cost = (cost as f64 * machine.copy_contention).round() as Cycles;
                    }
                    let release = if honor_starts { *sched_start } else { 0 };
                    let begin = clock[c].max(release);
                    let wait = begin - clock[c];
                    let start = clock[c];
                    clock[c] = begin + cost;
                    timeline[c].push(TimelineEntry {
                        desc: g.name(*node).to_string(),
                        node: Some(*node),
                        start,
                        end: clock[c],
                        wait,
                    });
                    total_wait += wait;
                    let e = node_cycles.entry(*node).or_insert(0);
                    *e = (*e).max(cost);
                    pc[c] += 1;
                    progressed = true;
                }
                CoreStep::Write { comm } => {
                    let key = (comm.src_core, comm.dst_core);
                    let chan = chans.entry(key).or_default();
                    // In-order writes; at most `cap` unconsumed messages.
                    let writable = chan.write_done.len() == comm.seq
                        && comm.seq < chan.read_done.len() + cap;
                    if writable {
                        // If the buffer slot was freed later than we arrive,
                        // we wait — §5.5 Obs. 3's write-side delay.
                        let freed_at = if comm.seq >= cap {
                            chan.read_done[comm.seq - cap]
                        } else {
                            0
                        };
                        let ready_at = freed_at.max(clock[c]);
                        let wait = ready_at - clock[c];
                        let base = (machine.comm_cycles)(machine.payload(comm.src));
                        let cost = jittered(
                            &mut rng,
                            plat.comm(comm.src_core, comm.dst_core, base),
                            machine,
                        );
                        let start = clock[c];
                        clock[c] = ready_at + cost;
                        chan.write_done.push(clock[c]);
                        max_unread = max_unread.max(chan.write_done.len() - chan.read_done.len());
                        timeline[c].push(TimelineEntry {
                            desc: format!("Write {}", comm.tag()),
                            node: None,
                            start,
                            end: clock[c],
                            wait,
                        });
                        total_wait += wait;
                        write_wait += wait;
                        pc[c] += 1;
                        progressed = true;
                    }
                }
                CoreStep::Read { comm } => {
                    let key = (comm.src_core, comm.dst_core);
                    let chan = chans.entry(key).or_default();
                    let readable = chan.read_done.len() == comm.seq
                        && chan.write_done.len() > comm.seq;
                    if readable {
                        let ready_at = chan.write_done[comm.seq].max(clock[c]);
                        let wait = ready_at - clock[c];
                        let base = (machine.comm_cycles)(machine.payload(comm.src));
                        let cost = jittered(
                            &mut rng,
                            plat.comm(comm.src_core, comm.dst_core, base),
                            machine,
                        );
                        let start = clock[c];
                        clock[c] = ready_at + cost;
                        chan.read_done.push(clock[c]);
                        timeline[c].push(TimelineEntry {
                            desc: format!("Read {}", comm.tag()),
                            node: None,
                            start,
                            end: clock[c],
                            wait,
                        });
                        total_wait += wait;
                        pc[c] += 1;
                        progressed = true;
                    }
                }
            }
        }
        if pc.iter().enumerate().all(|(c, &p)| p == programs[c].steps.len()) {
            break;
        }
        if !progressed {
            panic!(
                "simulator deadlock: pcs={pc:?} — \
                 schedule-derived programs must be deadlock-free"
            );
        }
    }

    let report = SimReport {
        makespan: clock.into_iter().max().unwrap_or(0),
        per_core: timeline,
        node_cycles,
        total_wait,
        write_wait,
    };
    (report, max_unread)
}

/// Outcome of a K-iteration stream replay ([`simulate_stream`]).
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    /// Completion time of each iteration: the latest compute finish among
    /// that iteration's node copies.
    pub completions: Vec<Cycles>,
    /// Measured steady-state period — the completion delta between the
    /// last two iterations (0 with fewer than 2 iterations). For a valid
    /// rigid pipeline replayed WCET-exactly this equals the initiation
    /// interval, making measured throughput exactly `1 / II`.
    pub steady_period: Cycles,
    /// High-water mark of in-flight (written, not yet read) messages on
    /// any one channel — must stay within the pipeline's reported
    /// [`buffer_depth`](crate::sched::PipelineReport::buffer_depth).
    pub max_channel_occupancy: usize,
    /// Full replay report of the unrolled stream.
    pub report: SimReport,
}

/// Replay a K-iteration inference stream of a pipeline kernel: iteration
/// `k` executes node copy `k · g.n() + v` on the kernel's core for `v`,
/// released at the kernel start shifted by `k · ii` (scheduled starts are
/// *release times* here — the stream must not race ahead of the
/// initiation interval, or measured throughput would be meaningless).
/// Payload bytes and copy-class markers of the per-iteration machine are
/// replicated to every copy; an explicit platform cost table is
/// replicated via [`unroll_platform`].
///
/// Validates `sched::pipeline` end to end: with `machine` set to the
/// WCET-exact replay machine and `channel_capacity` at the reported
/// buffer depth, completions advance by exactly `ii` per iteration and
/// no channel ever holds more messages than the reported depth
/// (`tests/pipeline_determinism.rs` pins both).
pub fn simulate_stream(
    g: &Dag,
    platform: Option<&Platform>,
    kernel: &Schedule,
    ii: Cycles,
    iterations: usize,
    machine: &Machine,
) -> StreamOutcome {
    assert!(iterations >= 1, "stream needs at least one iteration");
    assert!(ii >= 1, "initiation interval must be positive");
    let n = g.n();
    let gk = unroll_dag(g, iterations);
    let plat_k = platform.map(|p| unroll_platform(p, iterations));
    let plat = ResolvedPlatform::resolve(plat_k.as_ref(), &gk, kernel.m.max(1));
    let mut sched = Schedule::new(kernel.m.max(1));
    for k in 0..iterations {
        let off = (k as u64) * ii;
        for p in kernel.iter() {
            sched.place_raw(p.node + k * n, p.core, p.start + off, p.finish + off);
        }
    }
    let mut mach = machine.clone();
    for k in 1..iterations {
        for (&v, &bytes) in &machine.payload_bytes {
            mach.payload_bytes.insert(v + k * n, bytes);
        }
        for &v in &machine.copy_nodes {
            mach.copy_nodes.push(v + k * n);
        }
    }
    let (report, max_channel_occupancy) = run_sim(&gk, &plat, &sched, &mach, true);
    let mut completions = vec![0u64; iterations];
    for row in &report.per_core {
        for entry in row {
            if let Some(v) = entry.node {
                let k = v / n;
                completions[k] = completions[k].max(entry.end);
            }
        }
    }
    let steady_period = if iterations >= 2 {
        completions[iterations - 1] - completions[iterations - 2]
    } else {
        0
    };
    StreamOutcome { completions, steady_period, max_channel_occupancy, report }
}

/// Simulate the serial (single-core) execution of the whole DAG — the
/// baseline of Eq. (15) and Table 3's "Single-core" column.
pub fn simulate_serial(g: &Dag, machine: &Machine) -> SimReport {
    let mut s = Schedule::new(1);
    let mut t = 0;
    for v in g.topo_order() {
        s.place(g, v, 0, t);
        t += g.wcet(v);
    }
    simulate(g, &s, machine)
}

fn zero_comm(_: usize) -> Cycles {
    0
}

/// Convenience: WCET-exact machine with zero-cost communication (pure
/// schedule replay, useful for validating schedulers against `makespan()`).
pub fn replay_machine() -> Machine {
    Machine::exact(zero_comm)
}

#[cfg(test)]
// These tests pin the deprecated legacy entry points byte-identically
// until the parity suites retire them.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::paper_example_dag;
    use crate::sched::dsh::Dsh;
    use crate::sched::ish::Ish;
    use crate::sched::Scheduler;

    fn fixed_comm(_: usize) -> Cycles {
        3
    }

    #[test]
    fn serial_run_sums_wcets() {
        let g = paper_example_dag();
        let r = simulate_serial(&g, &replay_machine());
        assert_eq!(r.makespan, g.total_wcet());
        assert_eq!(r.total_wait, 0);
    }

    #[test]
    fn parallel_replay_close_to_schedule_makespan() {
        // With zero comm cost the simulated makespan can beat the schedule
        // (events fire as soon as flags allow) but never exceed it by the
        // protocol's serialization alone on ISH schedules (no duplication).
        let g = paper_example_dag();
        for m in 2..=4 {
            let sched = Ish.schedule(&g, m).schedule;
            let r = simulate(&g, &sched, &replay_machine());
            // Zero-latency sim: schedule makespan assumed comm w(e) > 0,
            // so the sim can only be faster or equal.
            assert!(
                r.makespan <= sched.makespan(),
                "m={m}: sim {} > sched {}",
                r.makespan,
                sched.makespan()
            );
        }
    }

    #[test]
    fn comm_cost_appears_in_timeline() {
        let mut g = crate::graph::Dag::new();
        let a = g.add_node("a", 2);
        let b = g.add_node("b", 3);
        g.add_edge(a, b, 5);
        let mut s = Schedule::new(2);
        s.place(&g, a, 0, 0);
        s.place(&g, b, 1, 7);
        let mut machine = Machine::exact(fixed_comm);
        machine.payload_bytes.insert(a, 16);
        let r = simulate(&g, &s, &machine);
        // Core 0: a (2) + write (3) = 5. Core 1: read ends 2+3+3=8? No —
        // read waits for write completion at 5, then costs 3 → 8; b: 8+3=11.
        assert_eq!(r.makespan, 11);
        let core1: Vec<&str> = r.per_core[1].iter().map(|e| e.desc.as_str()).collect();
        assert_eq!(core1, vec!["Read 0_1_a", "b"]);
        assert!(r.total_wait > 0, "reader must have waited for the writer");
    }

    #[test]
    fn single_buffer_backpressure_delays_writer() {
        // Two messages on the same channel: the writer cannot publish msg 1
        // until the reader consumed msg 0 (§5.2).
        let mut g = crate::graph::Dag::new();
        let a = g.add_node("a", 1);
        let b = g.add_node("b", 1);
        let c = g.add_node("c", 10); // delays the reads on core 1
        let d = g.add_node("d", 1);
        let e = g.add_node("e", 1);
        g.add_edge(a, d, 1);
        g.add_edge(b, e, 1);
        g.add_edge(a, c, 1); // keeps c on core 1 busy first? c independent
        let mut s = Schedule::new(2);
        s.place(&g, a, 0, 0);
        s.place(&g, b, 0, 1);
        s.place(&g, c, 1, 1); // c runs long on core 1
        s.place(&g, d, 1, 11);
        s.place(&g, e, 1, 12);
        let machine = Machine::exact(fixed_comm);
        let r = simulate(&g, &s, &machine);
        // Writer core 0 writes msg0 (for d) at 1+3=4; then must wait for
        // the reader (busy running c until 11 + read latency) before msg1.
        let writes: Vec<&TimelineEntry> = r.per_core[0]
            .iter()
            .filter(|t| t.desc.starts_with("Write"))
            .collect();
        assert_eq!(writes.len(), 2);
        assert!(
            writes[1].wait > 0,
            "second write must block on the unconsumed buffer: {writes:?}"
        );
    }

    #[test]
    fn jitter_changes_times_but_not_correctness() {
        let g = paper_example_dag();
        let sched = Dsh.schedule(&g, 3).schedule;
        let mut machine = replay_machine();
        machine.jitter = 0.3;
        machine.seed = 9;
        let r1 = simulate(&g, &sched, &machine);
        machine.seed = 10;
        let r2 = simulate(&g, &sched, &machine);
        assert!(r1.makespan != r2.makespan || r1.total_wait != r2.total_wait);
        // All nodes executed.
        for v in 0..g.n() {
            assert!(r1.node_cycles.contains_key(&v), "node {v} missing");
        }
    }

    #[test]
    fn platform_scaled_compute_doubles_on_the_slow_core() {
        use crate::sched::{Platform, SPEED_SCALE};
        let mut g = crate::graph::Dag::new();
        let a = g.add_node("a", 4);
        let b = g.add_node("b", 4);
        g.add_edge(a, b, 1);
        let plat = ResolvedPlatform::resolve(
            Some(&Platform::two_class(2, 1, SPEED_SCALE / 2)),
            &g,
            2,
        );
        // Both nodes on the slow core 1: each costs 8 instead of 4.
        let mut s = Schedule::new(2);
        s.place_on(&plat, a, 1, 0);
        s.place_on(&plat, b, 1, 8);
        let r = simulate_on(&g, &plat, &s, &replay_machine());
        assert_eq!(r.makespan, 16);
        assert_eq!(r.node_cycles[&a], 8);
        // Same schedule shape on the fast core 0 replays the raw WCETs.
        let mut f = Schedule::new(2);
        f.place_on(&plat, a, 0, 0);
        f.place_on(&plat, b, 0, 4);
        let rf = simulate_on(&g, &plat, &f, &replay_machine());
        assert_eq!(rf.makespan, 8);
        // The uniform wrapper stays byte-identical to the old behavior.
        let ru = simulate(&g, &f, &replay_machine());
        assert_eq!(ru.makespan, 8);
    }

    #[test]
    fn uniform_platform_comm_replay_is_byte_identical() {
        // The comm-routing satellite: pricing Write/Read through
        // `plat.comm` must leave the uniform replay untouched, timeline
        // entry for timeline entry, even with nonzero payload costs.
        use crate::sched::Platform;
        let mut g = crate::graph::Dag::new();
        let a = g.add_node("a", 2);
        let b = g.add_node("b", 3);
        g.add_edge(a, b, 5);
        let mut s = Schedule::new(2);
        s.place(&g, a, 0, 0);
        s.place(&g, b, 1, 7);
        let mut machine = Machine::exact(fixed_comm);
        machine.payload_bytes.insert(a, 16);
        let bare = simulate(&g, &s, &machine);
        let plat = ResolvedPlatform::resolve(Some(&Platform::uniform(2)), &g, 2);
        let uni = simulate_on(&g, &plat, &s, &machine);
        assert_eq!(bare.makespan, uni.makespan);
        assert_eq!(bare.total_wait, uni.total_wait);
        assert_eq!(bare.write_wait, uni.write_wait);
        let flat = |r: &SimReport| -> Vec<(String, Cycles, Cycles, Cycles)> {
            r.per_core
                .iter()
                .flatten()
                .map(|t| (t.desc.clone(), t.start, t.end, t.wait))
                .collect()
        };
        assert_eq!(flat(&bare), flat(&uni));
    }

    #[test]
    fn comm_factors_scale_write_and_read_costs() {
        // Nominal speeds but a 2x class-to-class comm factor: only the
        // Write/Read operators slow down. Baseline topology replays at
        // makespan 11 (see comm_cost_appears_in_timeline); doubling the
        // comm cost 3 -> 6 moves it to 2+6=8 (write), read 8..14, b 14..17.
        use crate::sched::{Platform, SPEED_SCALE};
        let mut g = crate::graph::Dag::new();
        let a = g.add_node("a", 2);
        let b = g.add_node("b", 3);
        g.add_edge(a, b, 5);
        let mut s = Schedule::new(2);
        s.place(&g, a, 0, 0);
        s.place(&g, b, 1, 7);
        let mut machine = Machine::exact(fixed_comm);
        machine.payload_bytes.insert(a, 16);
        let mut p = Platform::uniform(2);
        p.comm_factors = vec![vec![2 * SPEED_SCALE]];
        let plat = ResolvedPlatform::resolve(Some(&p), &g, 2);
        let r = simulate_on(&g, &plat, &s, &machine);
        assert_eq!(r.makespan, 17);
    }

    #[test]
    fn stream_replay_paces_at_the_initiation_interval() {
        // A two-stage kernel (a on core 0, b on core 1, span 3) streamed
        // for six iterations completes one inference every II cycles.
        let mut g = crate::graph::Dag::new();
        let a = g.add_node("a", 2);
        let b = g.add_node("b", 3);
        g.add_edge(a, b, 1);
        let mut kernel = Schedule::new(2);
        kernel.place(&g, a, 0, 0);
        kernel.place(&g, b, 1, 3);
        let mut machine = replay_machine();
        machine.channel_capacity = 2;
        let out = simulate_stream(&g, None, &kernel, 3, 6, &machine);
        assert_eq!(out.completions.len(), 6);
        for k in 1..6 {
            assert_eq!(out.completions[k] - out.completions[k - 1], 3);
        }
        assert_eq!(out.steady_period, 3);
        assert!(out.max_channel_occupancy <= 2);
    }

    #[test]
    fn copy_contention_slows_marked_nodes() {
        let g = paper_example_dag();
        let sched = Dsh.schedule(&g, 2).schedule;
        let base = simulate(&g, &sched, &replay_machine());
        let mut machine = replay_machine();
        machine.copy_contention = 3.0;
        machine.copy_nodes = vec![0];
        let slow = simulate(&g, &sched, &machine);
        assert!(slow.node_cycles[&0] >= base.node_cycles[&0]);
    }
}
