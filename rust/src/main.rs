//! `acetone` — command-line interface of the coordinator.
//!
//! Subcommands (args are `--key value` pairs; see `acetone help`):
//!
//! * `export-models`  — write the model-zoo JSONs consumed by the Python
//!                      AOT path (`make artifacts` runs this first);
//! * `schedule`       — schedule a model or random DAG with any solver and
//!                      print the Gantt chart + makespan/speedup;
//! * `wcet`           — static WCET analysis (Table 1/2 style) + the §5.4
//!                      global composition for a parallel schedule;
//! * `simulate`       — run the cycle-level platform simulator (Table 3);
//! * `run`            — parallel PJRT inference over the AOT artifacts,
//!                      numerics checked against the single-core artifact;
//! * `codegen`        — emit ACETONE-style parallel C code;
//! * `serve`          — batch-solve a JSONL stream of scheduling requests
//!                      through the portfolio, deduplicated, optionally
//!                      over a persistent `--cache-dir` schedule cache;
//!                      with `--listen`, a persistent solver daemon with
//!                      admission control and a `stats` verb;
//! * `dag`            — generate a §4.1 random DAG (DOT output).

use acetone::graph::ensure_single_sink;
use acetone::nn::{eval::Tensor, model_json, numel, weights, zoo, Network};
use acetone::sched::portfolio::PortfolioConfig;
use acetone::sched::serve::{
    BatchRequest, BatchSolver, Daemon, DaemonConfig, ProblemSpec, SessionSummary,
};
use acetone::sched::pipeline::solve_pipeline;
use acetone::sched::{
    bnb::ChouChung, cp::CpSolver, dsh::Dsh, hlfet::Hlfet, hybrid::Hybrid, ish::Ish,
    portfolio::Portfolio, Budget, CancelToken, CpGlobals, CpOptions, PipelineRequest,
    PipelineSolver, Platform, Scheduler, SearchOptions, SolveRequest, Termination, SPEED_SCALE,
};
use acetone::util::json::Json;
use acetone::wcet::CostModel;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

/// The `help` text: every subcommand with every `--flag` it parses. The
/// `help_covers_every_parsed_flag` test scrapes this file for option
/// accessors and fails when a parsed flag is missing here, so the text
/// cannot silently drift from the parser.
const HELP: &str = "\
acetone — parallel C/PJRT inference for certifiable DNNs

usage: acetone <cmd> [--key value]...

export-models --dir D
    write the model-zoo JSONs consumed by the Python AOT path
schedule --model M | --nodes N [--seed S] [--density D]
         --cores C --algo A [--timeout S] [--node-limit N]
         [--pipeline true [--exact true]]
    schedule a model or random DAG, print makespan/speedup/verdict + Gantt
    (algo: hlfet|ish|dsh|cp|tang|bnb|hybrid|portfolio; a --node-limit
     makes truncated exact runs machine-independent).
    --pipeline true switches to steady-state throughput mode: the report
    is the initiation interval II (one inference admitted every II
    cycles), its admissible lower bound, the fill/drain latency and the
    per-channel buffer depth; --exact true certifies II on the unrolled
    2-iteration kernel via the exact portfolio when the budget allows
wcet --cores C [--model googlenet:paper]
    static per-layer WCET table + the global composition for a schedule
simulate --model M --cores C [--jitter J] [--seed S]
    cycle-level platform simulation (Table 3)
run --model M --cores C [--artifacts DIR] [--algo A] [--timeout S] [--node-limit N]
    parallel PJRT inference over the AOT artifacts, numerics-checked
codegen --model M --cores C --out DIR [--algo A] [--timeout S] [--node-limit N]
    emit the ACETONE-style parallel C project
serve --requests FILE.jsonl [--cores C] [--workers W] [--cache-dir DIR]
      [--timeout S] [--node-limit N] [--nogood-capacity K]
      [--cp-disjunctive true] [--cp-binpacking true]
      [--listen SOCKET|-] [--max-inflight N] [--cache-budget BYTES]
    batch-solve a JSONL request stream through the portfolio: requests
    are deduplicated by canonical key, fanned out over one worker pool
    and answered in input order; with --cache-dir, solved schedules
    (verdicts included) persist across processes. Each line is one JSON
    object using the schedule flags as keys: {\"model\": \"lenet5\"} or
    {\"nodes\": 50, \"seed\": 1, \"density\": 0.1}, plus optional
    \"cores\", \"node-limit\", \"timeout\", \"nogood-capacity\",
    \"cp-disjunctive\", \"cp-binpacking\" overriding the CLI defaults
    (a no-good capacity > 0 turns on conflict-driven learning in the
    exact stages for that request; the cp-* booleans switch on the CP
    stage's global scheduling propagators — disjunctive edge-finding
    and the bin-packing load bound — for that request).
    A heterogeneous platform is described per line by \"speeds\" (one
    positive factor per core, 1.0 = nominal, larger = faster),
    \"core-classes\" (core -> class map) and \"comm-matrix\" (square
    class x class latency factors); omitted pieces default to nominal,
    and an all-nominal platform solves (and caches) exactly like no
    platform at all.
    A line may carry an \"id\" string echoed in its response (default
    line-<n>; duplicates are rejected naming both lines) and
    \"cancelled\": true to mark a client that went away (answered by
    the serial fallback). \"mode\": \"pipeline\" answers with the
    steady-state pipeline report (initiation interval \"ii\", its
    admissible \"bound\", fill/drain \"latency\", buffer \"depth\")
    instead of a one-shot makespan; \"stream-depth\" declares the
    client's per-channel buffer capacity and adds a boolean \"fits\"
    to the response. With --listen (unix socket path, or - for
    stdio) serve becomes a persistent daemon: request lines are
    admitted into a bounded queue (--max-inflight, default 64; excess
    lines get an immediate {\"rejected\": true} response), the queued
    window dispatches at {\"verb\": \"flush\"} / {\"verb\":
    \"shutdown\"} / EOF, and every request is answered with one JSON
    line tagged by its id. {\"verb\": \"cancel\", \"id\": I} fires
    request I's cancel token (a queued request is answered by the
    serial fallback); {\"verb\": \"stats\"} reports cache
    hit/miss/eviction and compaction counters, queue depth, admission
    rejections and per-stage wall times. --cache-budget BYTES bounds
    the persistent L2 log, evicting oldest records first; compaction
    reclaims dead bytes automatically in both modes.
dag --nodes N [--seed S] [--density D]
    generate a §4.1 random DAG (DOT output)
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--key value` argument bag.
struct Opts(HashMap<String, String>);

impl Opts {
    fn parse(rest: &[String]) -> Result<Self> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {}", rest[i]))?;
            let v = rest
                .get(i + 1)
                .ok_or_else(|| anyhow!("missing value for --{k}"))?;
            map.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Self(map))
    }
    fn get(&self, k: &str) -> Option<&str> {
        self.0.get(k).map(String::as_str)
    }
    /// Parse `--k`; absent → `default`, malformed → hard error naming the
    /// flag (a silent default on `--budget 2x` would hide the typo).
    fn parsed<T: std::str::FromStr>(&self, k: &str, default: T) -> Result<T> {
        self.opt_parsed(k).map(|v| v.unwrap_or(default))
    }
    /// Parse an optional `--k`; absent → `None`, malformed → hard error.
    fn opt_parsed<T: std::str::FromStr>(&self, k: &str) -> Result<Option<T>> {
        match self.get(k) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| anyhow!("invalid value for --{k}: {v:?}")),
        }
    }
    fn usize(&self, k: &str, default: usize) -> Result<usize> {
        self.parsed(k, default)
    }
    fn u64(&self, k: &str, default: u64) -> Result<u64> {
        self.parsed(k, default)
    }
    fn f64(&self, k: &str, default: f64) -> Result<f64> {
        self.parsed(k, default)
    }
}

/// Resolve a zoo model by name; suffix `:paper` selects the paper-scale
/// variant (WCET analysis only), default is the executable tiny scale.
fn model_by_name(name: &str) -> Result<Network> {
    let (base, scale) = match name.split_once(':') {
        Some((b, "paper")) => (b, zoo::Scale::Paper),
        Some((b, "tiny")) => (b, zoo::Scale::Tiny),
        Some((_, other)) => bail!("unknown scale {other} (tiny|paper)"),
        None => (name, zoo::Scale::Tiny),
    };
    Ok(match base {
        "lenet5" => zoo::lenet5(scale),
        "lenet5_split" => zoo::lenet5_split(scale),
        "googlenet" => zoo::googlenet(scale),
        "mlp" => zoo::mlp("mlp", &[64, 128, 64, 10]),
        other => bail!("unknown model {other} (lenet5|lenet5_split|googlenet|mlp)"),
    })
}

/// Solvers carry no budgets: the deadline and node limit come from the
/// per-run [`SolveRequest`] assembled by each subcommand.
fn solver_by_name(name: &str) -> Result<Box<dyn Scheduler>> {
    Ok(match name {
        "hlfet" => Box::new(Hlfet),
        "ish" => Box::new(Ish),
        "dsh" => Box::new(Dsh),
        "cp" | "improved" => Box::new(CpSolver::improved()),
        "tang" => Box::new(CpSolver::tang()),
        "bnb" => Box::new(ChouChung::default()),
        "hybrid" => Box::new(Hybrid),
        "portfolio" => Box::new(Portfolio::default()),
        other => bail!("unknown algo {other} (hlfet|ish|dsh|cp|tang|bnb|hybrid|portfolio)"),
    })
}

/// The unified `--timeout` / `--node-limit` budget of a CLI run. A node
/// budget makes truncated runs machine-independent (the same search tree
/// everywhere); the timeout stays a wall-clock safety valve.
fn budget_from(opts: &Opts) -> Result<Budget> {
    Ok(Budget {
        deadline: Some(Duration::from_secs(opts.u64("timeout", 10)?)),
        node_limit: opts.opt_parsed("node-limit")?,
    })
}

/// One-word CLI rendering of a termination verdict (the daemon's JSONL
/// responses use the same [`Termination::as_str`] words).
fn verdict(t: &Termination) -> &'static str {
    t.as_str()
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let opts = Opts::parse(&args[1.min(args.len())..])?;
    match cmd {
        "export-models" => export_models(&opts),
        "schedule" => schedule_cmd(&opts),
        "wcet" => wcet_cmd(&opts),
        "simulate" => simulate_cmd(&opts),
        "run" => run_cmd(&opts),
        "codegen" => codegen_cmd(&opts),
        "serve" => serve_cmd(&opts),
        "dag" => dag_cmd(&opts),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn export_models(opts: &Opts) -> Result<()> {
    let dir = opts.get("dir").unwrap_or("artifacts/models");
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
    for net in [
        zoo::lenet5(zoo::Scale::Tiny),
        zoo::lenet5_split(zoo::Scale::Tiny),
        zoo::googlenet(zoo::Scale::Tiny),
        zoo::mlp("mlp", &[64, 128, 64, 10]),
    ] {
        let path = format!("{dir}/{}.json", net.name);
        std::fs::write(&path, model_json::to_json(&net).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn load_graph(opts: &Opts) -> Result<(acetone::graph::Dag, Option<Network>)> {
    if let Some(m) = opts.get("model") {
        let net = model_by_name(m)?;
        let g = net.to_dag(&CostModel::default());
        Ok((g, Some(net)))
    } else {
        let n = opts.usize("nodes", 20)?;
        let seed = opts.u64("seed", 1)?;
        let mut cfg = acetone::daggen::DagGenConfig::paper(n);
        cfg.density = opts.f64("density", 0.10)?;
        Ok((acetone::daggen::generate(&cfg, seed), None))
    }
}

fn schedule_cmd(opts: &Opts) -> Result<()> {
    let (mut g, _) = load_graph(opts)?;
    ensure_single_sink(&mut g);
    let m = opts.usize("cores", 4)?;
    let budget = budget_from(opts)?;
    if opts.parsed("pipeline", false)? {
        return pipeline_cmd(&g, m, budget, opts);
    }
    let solver = solver_by_name(opts.get("algo").unwrap_or("dsh"))?;
    let r = solver.solve(&SolveRequest::new(&g, m).budget(budget));
    acetone::sched::check_valid(&g, &r.schedule)
        .map_err(|e| anyhow!("solver produced invalid schedule: {e}"))?;
    println!(
        "{} on {m} cores: makespan={} speedup={:.3} duplicates={} verdict={} time={:?} \
         explored={} pruned={} leaves={}",
        solver.name(),
        r.schedule.makespan(),
        r.schedule.speedup(&g),
        r.schedule.duplication_count(),
        verdict(&r.termination),
        r.stats.wall,
        r.stats.explored,
        r.stats.pruned,
        r.stats.leaves,
    );
    for stage in &r.stats.stages {
        println!("  stage {:<16} wall={:?} explored={}", stage.name, stage.wall, stage.explored);
    }
    if r.stats.nogoods_recorded > 0 || r.stats.restarts > 0 {
        println!(
            "  learning: nogoods={} hits={} flushes={} restarts={} max-depth={}",
            r.stats.nogoods_recorded,
            r.stats.nogood_hits,
            r.stats.nogood_flushes,
            r.stats.restarts,
            r.stats.max_depth
        );
    }
    if g.n() <= 64 && g.total_wcet() <= 512 {
        println!("{}", r.schedule.gantt(&g));
    }
    Ok(())
}

/// `schedule --pipeline true`: steady-state throughput mode. The report
/// is the one-iteration kernel plus its initiation interval — a new
/// inference is admitted every II cycles, so throughput is 1/II.
fn pipeline_cmd(g: &acetone::graph::Dag, m: usize, budget: Budget, opts: &Opts) -> Result<()> {
    let exact = opts.parsed("exact", false)?;
    let solver = PipelineSolver::default();
    let r = solver.solve(&PipelineRequest::new(g, m).budget(budget).exact(exact));
    acetone::sched::check_valid(g, &r.kernel)
        .map_err(|e| anyhow!("pipeline produced an invalid kernel: {e}"))?;
    println!(
        "pipeline on {m} cores: ii={} (bound {}) latency={} buffer-depth={} verdict={} \
         time={:?} explored={}",
        r.ii,
        r.lower_bound,
        r.latency,
        r.buffer_depth,
        verdict(&r.termination),
        r.stats.wall,
        r.stats.explored,
    );
    for stage in &r.stats.stages {
        println!("  stage {:<16} wall={:?} explored={}", stage.name, stage.wall, stage.explored);
    }
    if g.n() <= 64 && g.total_wcet() <= 512 {
        println!("{}", r.kernel.gantt(g));
    }
    Ok(())
}

fn wcet_cmd(opts: &Opts) -> Result<()> {
    let name = opts.get("model").unwrap_or("googlenet:paper");
    let net = model_by_name(name)?;
    let cm = CostModel::default();
    let table = acetone::wcet::layer_table(&net, &cm);
    let mut t = acetone::metrics::Table::new(&["Layer Name", "WCET [cycles]"]);
    let mut total = 0u64;
    for (lname, cycles) in &table {
        t.row(vec![lname.clone(), acetone::metrics::sci(*cycles as f64)]);
        total += cycles;
    }
    t.row(vec!["Total Sum".into(), acetone::metrics::sci(total as f64)]);
    println!("{}", t.markdown());

    let m = opts.usize("cores", 4)?;
    let g = net.to_dag(&cm);
    let sched = Dsh.solve(&SolveRequest::new(&g, m)).schedule;
    let shapes = net.shapes();
    let bytes = move |v: usize| numel(&shapes[v]) * 4;
    let composed = acetone::wcet::compose_global(&g, &sched, &cm, &bytes);
    let serial = acetone::wcet::serial_global(&g);
    println!(
        "global WCET: serial={} parallel({m} cores)={} gain={:.1}%",
        acetone::metrics::sci(serial as f64),
        acetone::metrics::sci(composed.makespan as f64),
        100.0 * (1.0 - composed.makespan as f64 / serial as f64)
    );
    Ok(())
}

fn simulate_cmd(opts: &Opts) -> Result<()> {
    let name = opts.get("model").unwrap_or("googlenet:paper");
    let net = model_by_name(name)?;
    let cm = CostModel::default();
    let g = net.to_dag(&cm);
    let m = opts.usize("cores", 4)?;
    let sched = Dsh.solve(&SolveRequest::new(&g, m)).schedule;
    let shapes = net.shapes();
    let mut machine = acetone::sim::Machine::exact(sim_comm_cost);
    for (i, s) in shapes.iter().enumerate() {
        machine.payload_bytes.insert(i, numel(s) * 4);
    }
    machine.jitter = opts.f64("jitter", 0.0)?;
    machine.seed = opts.u64("seed", 0)?;
    let serial = acetone::sim::simulate_serial(&g, &machine);
    let par = acetone::sim::simulate(&g, &sched, &machine);
    println!(
        "simulated: serial={} parallel={} speedup={:.3} wait={}",
        serial.makespan,
        par.makespan,
        par.speedup(serial.makespan),
        par.total_wait
    );
    Ok(())
}

/// Communication cost for the simulator CLI: the default CostModel's
/// Table-2 bound applied to the payload size.
fn sim_comm_cost(bytes: usize) -> u64 {
    CostModel::default().comm_wcet(bytes)
}

fn run_cmd(opts: &Opts) -> Result<()> {
    let name = opts.get("model").unwrap_or("lenet5_split");
    let net = model_by_name(name)?;
    let m = opts.usize("cores", 2)?;
    let dir = opts.get("artifacts").unwrap_or("artifacts");
    let manifest = acetone::runtime::Manifest::load(dir)?;
    let mm = manifest
        .models
        .get(&net.name)
        .ok_or_else(|| anyhow!("model {} not in manifest", net.name))?;
    let g = net.to_dag(&CostModel::default());
    let budget = Budget {
        deadline: Some(Duration::from_secs(opts.u64("timeout", 5)?)),
        node_limit: opts.opt_parsed("node-limit")?,
    };
    let solver = solver_by_name(opts.get("algo").unwrap_or("dsh"))?;
    let sched = solver.solve(&SolveRequest::new(&g, m).budget(budget)).schedule;
    let shapes = net.shapes();
    let input = Tensor::new(
        shapes[0].clone(),
        weights::input_tensor(numel(&shapes[0]), mm.seed),
    );
    let (par_out, report) = acetone::exec::run_parallel(&net, &sched, mm, dir, &input)?;
    let (ref_out, ref_wall) = acetone::exec::run_full(mm, dir, &input)?;
    let max_err = par_out
        .data
        .iter()
        .zip(&ref_out.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "{} on {m} cores: wall={:?} single-core-artifact={:?} max|Δ|={max_err:.2e}",
        net.name, report.wall, ref_wall
    );
    if max_err > 1e-3 {
        bail!("numerics mismatch vs single-core artifact");
    }
    println!("numerics OK ({} steps)", report.steps.len());
    Ok(())
}

fn codegen_cmd(opts: &Opts) -> Result<()> {
    let name = opts.get("model").unwrap_or("lenet5_split");
    let net = model_by_name(name)?;
    let m = opts.usize("cores", 2)?;
    let out = opts.get("out").unwrap_or("generated_c");
    let g = net.to_dag(&CostModel::default());
    let budget = budget_from(opts)?;
    let solver = solver_by_name(opts.get("algo").unwrap_or("dsh"))?;
    let r = solver.solve(&SolveRequest::new(&g, m).budget(budget));
    println!(
        "schedule: {} makespan={} verdict={}",
        solver.name(),
        r.schedule.makespan(),
        verdict(&r.termination)
    );
    let dir = acetone::codegen::generate_project(&net, &r.schedule, 42, std::path::Path::new(out))?;
    println!("generated C project at {}", dir.display());
    Ok(())
}

/// One parsed line of the `serve` JSONL stream: the problem is
/// materialized into an owned `Dag` first (requests borrow them).
struct ServeSpec {
    /// `id` key, echoed in the output (`line-<n>` when absent). Batch
    /// mode hard-errors on duplicates; the daemon rejects the line and
    /// keeps serving.
    id: String,
    /// `cancelled` key: the client was gone before dispatch — answered
    /// by the serial fallback without running a solve.
    cancelled: bool,
    g: acetone::graph::Dag,
    m: usize,
    budget: Budget,
    /// `nogood-capacity` key: a capacity > 0 turns on conflict-driven
    /// learning in the exact stages for this request.
    nogood_capacity: Option<u64>,
    /// `speeds` / `core-classes` / `comm-matrix` keys: the heterogeneous
    /// platform of this request, validated with the line number.
    platform: Option<Platform>,
    /// `cp-disjunctive` / `cp-binpacking` keys: the CP stage's global
    /// scheduling propagators for this request (`None` = both off).
    cp_globals: Option<CpGlobals>,
    /// `mode` key: `"pipeline"` answers with a steady-state pipeline
    /// report (ii/latency/depth) instead of a one-shot makespan.
    pipeline: bool,
    /// `stream-depth` key: the client's per-channel buffer capacity —
    /// pipeline responses report whether the schedule fits it.
    stream_depth: Option<usize>,
}

/// CLI-level request defaults every JSONL line may override.
struct ServeDefaults {
    cores: usize,
    timeout: u64,
    node_limit: Option<u64>,
    nogood_capacity: Option<u64>,
    cp_disjunctive: bool,
    cp_binpacking: bool,
}

impl ServeDefaults {
    fn from_opts(opts: &Opts) -> Result<Self> {
        Ok(Self {
            cores: opts.usize("cores", 4)?,
            timeout: opts.u64("timeout", 10)?,
            node_limit: opts.opt_parsed("node-limit")?,
            nogood_capacity: opts.opt_parsed("nogood-capacity")?,
            cp_disjunctive: opts.parsed("cp-disjunctive", false)?,
            cp_binpacking: opts.parsed("cp-binpacking", false)?,
        })
    }
}

/// Lower a parsed request line into the library's owned problem form
/// (the daemon path; `id`/`cancelled` are handled by the daemon itself).
fn spec_to_problem(spec: ServeSpec) -> ProblemSpec {
    ProblemSpec {
        g: spec.g,
        m: spec.m,
        budget: spec.budget,
        platform: spec.platform,
        search: spec.nogood_capacity.map(|cap| SearchOptions {
            nogood_capacity: Some(cap as usize),
            ..SearchOptions::default()
        }),
        cp_globals: spec.cp_globals,
        pipeline: spec.pipeline,
        stream_depth: spec.stream_depth,
    }
}

/// A boolean field of a serve request line, hard-erroring with the line
/// number on anything that is not a JSON `true`/`false` — the serve
/// request vocabulary never coerces (a string "true" stays an error).
fn json_bool(v: &Json, key: &str, lineno: usize) -> Result<Option<bool>> {
    match v.get(key) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => bail!("requests line {lineno}: {key:?} must be a boolean"),
    }
}

/// A non-negative integer field of a serve request line. Fractional or
/// negative numbers hard-error with the line number — the same rule the
/// `Opts` accessors apply to CLI flags (a silent `0.5 → 0` would turn a
/// typo into an already-expired deadline or a zero-node budget).
fn json_u64(v: &Json, key: &str, lineno: usize) -> Result<Option<u64>> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 => Ok(Some(f as u64)),
            _ => bail!("requests line {lineno}: {key:?} must be a non-negative integer"),
        },
    }
}

/// A positive fixed-point factor field (1.0 = nominal): `round(x · SCALE)`
/// over [`SPEED_SCALE`], hard-erroring with the line number on anything
/// non-positive, non-numeric, or so small it rounds to zero.
fn json_factor(x: &Json, what: &str, lineno: usize) -> Result<u32> {
    let f = x
        .as_f64()
        .ok_or_else(|| anyhow!("requests line {lineno}: {what} must be a number"))?;
    let scaled = (f * SPEED_SCALE as f64).round();
    if f <= 0.0 || scaled < 1.0 || scaled > u32::MAX as f64 {
        bail!("requests line {lineno}: {what} must be positive (got {f})");
    }
    Ok(scaled as u32)
}

/// The optional heterogeneous platform of one serve request line:
/// `speeds` (per-core factors), `core-classes` (core → class map) and
/// `comm-matrix` (square class × class factors). Any subset may be given;
/// the missing pieces default to nominal. Shape errors (wrong length,
/// ragged matrix, class out of range) hard-error with the line number.
fn json_platform(v: &Json, m: usize, lineno: usize) -> Result<Option<Platform>> {
    let (speeds_j, classes_j, comm_j) =
        (v.get("speeds"), v.get("core-classes"), v.get("comm-matrix"));
    if speeds_j.is_none() && classes_j.is_none() && comm_j.is_none() {
        return Ok(None);
    }
    let speeds = match speeds_j {
        None => vec![SPEED_SCALE; m],
        Some(a) => a
            .as_arr()
            .ok_or_else(|| anyhow!("requests line {lineno}: \"speeds\" must be an array"))?
            .iter()
            .enumerate()
            .map(|(c, x)| json_factor(x, &format!("\"speeds\"[{c}]"), lineno))
            .collect::<Result<Vec<_>>>()?,
    };
    let core_classes = match classes_j {
        None => vec![0; m],
        Some(a) => a
            .as_arr()
            .ok_or_else(|| anyhow!("requests line {lineno}: \"core-classes\" must be an array"))?
            .iter()
            .enumerate()
            .map(|(c, x)| match x.as_f64() {
                // `as_usize` saturates a negative to 0 — check the raw
                // number so a typo errors instead of naming class 0.
                Some(f) if f >= 0.0 && f.fract() == 0.0 => Ok(f as usize),
                _ => bail!(
                    "requests line {lineno}: \"core-classes\"[{c}] must be a \
                     non-negative integer"
                ),
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let comm_factors = match comm_j {
        // No matrix given: nominal communication between every named class.
        None => {
            let k = core_classes.iter().max().map_or(1, |&c| c + 1);
            vec![vec![SPEED_SCALE; k]; k]
        }
        Some(a) => a
            .as_arr()
            .ok_or_else(|| anyhow!("requests line {lineno}: \"comm-matrix\" must be an array"))?
            .iter()
            .enumerate()
            .map(|(i, row)| {
                row.as_arr()
                    .ok_or_else(|| {
                        anyhow!("requests line {lineno}: \"comm-matrix\" row {i} must be an array")
                    })?
                    .iter()
                    .enumerate()
                    .map(|(j, x)| json_factor(x, &format!("\"comm-matrix\"[{i}][{j}]"), lineno))
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let p = Platform { speeds, core_classes, comm_factors, cost_table: None };
    p.validate(m).map_err(|e| anyhow!("requests line {lineno}: {e}"))?;
    Ok(Some(p))
}

/// Parse one `serve` request line: the `schedule` flags as keys (`model`
/// *or* `nodes`/`seed`/`density`, plus optional `cores`, `node-limit`,
/// `timeout`, the platform keys — see [`json_platform`] — and the
/// daemon keys `id`/`cancelled`). Shared by the batch path and the
/// `--listen` daemon, so both speak the exact same request vocabulary.
fn parse_serve_line(v: &Json, defaults: &ServeDefaults, lineno: usize) -> Result<ServeSpec> {
    let id = match v.get("id") {
        None => format!("line-{lineno}"),
        Some(Json::Str(s)) => s.clone(),
        Some(_) => bail!("requests line {lineno}: \"id\" must be a string"),
    };
    let cancelled = match v.get("cancelled") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => bail!("requests line {lineno}: \"cancelled\" must be a boolean"),
    };
    let g = if let Some(name) = v.get("model").and_then(Json::as_str) {
        model_by_name(name)?.to_dag(&CostModel::default())
    } else if let Some(n) = json_u64(v, "nodes", lineno)? {
        if n == 0 {
            bail!("requests line {lineno}: \"nodes\" must be >= 1");
        }
        let mut cfg = acetone::daggen::DagGenConfig::paper(n as usize);
        if let Some(d) = v.get("density").and_then(Json::as_f64) {
            cfg.density = d;
        }
        let seed = json_u64(v, "seed", lineno)?.unwrap_or(1);
        acetone::daggen::generate(&cfg, seed)
    } else {
        bail!("requests line {lineno}: need \"model\" or \"nodes\"");
    };
    // Validate here with the line number rather than letting the
    // portfolio's `m >= 1` assertion abort the whole batch.
    let m = json_u64(v, "cores", lineno)?.map(|c| c as usize).unwrap_or(defaults.cores);
    if m == 0 {
        bail!("requests line {lineno}: \"cores\" must be >= 1");
    }
    let budget = Budget {
        deadline: Some(Duration::from_secs(
            json_u64(v, "timeout", lineno)?.unwrap_or(defaults.timeout),
        )),
        node_limit: json_u64(v, "node-limit", lineno)?.or(defaults.node_limit),
    };
    let nogood_capacity = json_u64(v, "nogood-capacity", lineno)?.or(defaults.nogood_capacity);
    let disjunctive =
        json_bool(v, "cp-disjunctive", lineno)?.unwrap_or(defaults.cp_disjunctive);
    let binpacking = json_bool(v, "cp-binpacking", lineno)?.unwrap_or(defaults.cp_binpacking);
    let cp_globals =
        (disjunctive || binpacking).then_some(CpGlobals { disjunctive, binpacking });
    let platform = json_platform(v, m, lineno)?;
    let pipeline = match v.get("mode") {
        None => false,
        Some(Json::Str(s)) if s == "pipeline" => true,
        Some(Json::Str(s)) if s == "solve" => false,
        Some(_) => bail!("requests line {lineno}: \"mode\" must be \"solve\" or \"pipeline\""),
    };
    let stream_depth = json_u64(v, "stream-depth", lineno)?.map(|d| d as usize);
    Ok(ServeSpec {
        id,
        cancelled,
        g,
        m,
        budget,
        nogood_capacity,
        platform,
        cp_globals,
        pipeline,
        stream_depth,
    })
}

/// Read a whole `serve` request stream (batch mode). Blank lines and `#`
/// comment lines are skipped; duplicate ids are a hard error here (the
/// daemon instead rejects the offending line and keeps serving).
fn parse_serve_stream(text: &str, opts: &Opts) -> Result<Vec<ServeSpec>> {
    let defaults = ServeDefaults::from_opts(opts)?;
    let mut specs: Vec<ServeSpec> = Vec::new();
    let mut seen_ids: HashMap<String, usize> = HashMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let lineno = lineno + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow!("requests line {lineno}: {e}"))?;
        let spec = parse_serve_line(&v, &defaults, lineno)?;
        if let Some(first) = seen_ids.insert(spec.id.clone(), lineno) {
            bail!(
                "requests line {lineno}: duplicate id {:?} (already used on line {first})",
                spec.id
            );
        }
        specs.push(spec);
    }
    Ok(specs)
}

fn serve_cmd(opts: &Opts) -> Result<()> {
    if opts.get("listen").is_some() {
        return serve_daemon_cmd(opts);
    }
    let path = opts.get("requests").ok_or_else(|| {
        anyhow!("--requests FILE.jsonl required (or --listen SOCKET|- for daemon mode)")
    })?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let specs = parse_serve_stream(&text, opts)?;
    if specs.is_empty() {
        bail!("{path} contains no requests");
    }
    let workers = opts.usize("workers", 0)?;
    let cfg = PortfolioConfig {
        cache_dir: opts.get("cache-dir").map(PathBuf::from),
        cache_budget: opts.opt_parsed("cache-budget")?,
        ..PortfolioConfig::default()
    };
    let server = BatchSolver::new(cfg);
    let mut batch = BatchRequest::new().workers(workers);
    for spec in specs.iter().filter(|s| !s.pipeline) {
        let mut req = SolveRequest::new(&spec.g, spec.m).budget(spec.budget.clone());
        if spec.cancelled {
            let token = CancelToken::new();
            token.cancel();
            req = req.cancel(token);
        }
        if let Some(p) = &spec.platform {
            req = req.platform(p.clone());
        }
        if let Some(cap) = spec.nogood_capacity {
            req = req.search(SearchOptions {
                nogood_capacity: Some(cap as usize),
                ..SearchOptions::default()
            });
        }
        if let Some(gl) = spec.cp_globals {
            req = req.cp(CpOptions { globals: Some(gl), ..CpOptions::default() });
        }
        batch = batch.push(req);
    }
    let out = server.solve_batch(&batch);
    let mut reports = out.reports.iter();
    for (i, spec) in specs.iter().enumerate() {
        if spec.pipeline {
            // Pipeline lines ride the shared cache individually (their
            // own key suffix — never a one-shot collision).
            let mut req = PipelineRequest::new(&spec.g, spec.m).budget(spec.budget.clone());
            if spec.cancelled {
                let token = CancelToken::new();
                token.cancel();
                req = req.cancel(token);
            }
            if let Some(p) = &spec.platform {
                req = req.platform(p.clone());
            }
            let r = solve_pipeline(server.portfolio(), &req);
            let fits = match spec.stream_depth {
                Some(cap) => format!(" fits({cap})={}", r.buffer_depth <= cap),
                None => String::new(),
            };
            println!(
                "#{i:<4} id={:<10} pipeline  ii={:<8} bound={:<8} latency={:<8} depth={:<4} \
                 verdict={:<18} explored={:<8} wall={:?}{fits}",
                spec.id,
                r.ii,
                r.lower_bound,
                r.latency,
                r.buffer_depth,
                verdict(&r.termination),
                r.stats.explored,
                r.stats.wall
            );
            continue;
        }
        let served = reports.next().expect("one batch report per one-shot spec");
        let r = &served.report;
        println!(
            "#{i:<4} id={:<10} {:<9} makespan={:<8} verdict={:<18} explored={:<8} \
             nogoods={:<6} wall={:?}",
            spec.id,
            served.source.as_str(),
            r.schedule.makespan(),
            verdict(&r.termination),
            r.stats.explored,
            r.stats.nogoods_recorded,
            r.stats.wall
        );
    }
    let s = out.stats;
    println!(
        "batch: {} requests → {} distinct solves ({} deduped, {} cache hits, \
         {} cancelled, {} DAG groups) in {:?}",
        s.requests, s.distinct, s.deduped, s.cache_hits, s.cancelled, s.dag_groups, s.wall
    );
    println!("cache: {:?}", server.portfolio().cache_stats());
    Ok(())
}

/// The daemon's per-line parser: the batch request vocabulary, lowered
/// to the library's [`ProblemSpec`]. Errors become per-line error
/// responses instead of killing the session.
fn line_parser(
    defaults: &ServeDefaults,
) -> impl FnMut(&Json, usize) -> Result<ProblemSpec, String> + '_ {
    move |v, lineno| {
        parse_serve_line(v, defaults, lineno).map(spec_to_problem).map_err(|e| format!("{e:#}"))
    }
}

/// One line of operator log per served session (stderr: stdout carries
/// the JSONL responses in `--listen -` mode). Counters are
/// daemon-lifetime, so over a socket they accumulate across connections.
fn log_session(s: &SessionSummary) {
    let t = s.totals;
    eprintln!(
        "session: {} lines → {} responses ({} solved, {} cache hits, {} deduped, \
         {} cancelled, {} errors, {} rejected){}",
        t.lines,
        t.responses,
        t.solved,
        t.cache_hits,
        t.deduped,
        t.cancelled,
        t.errors,
        s.queue.rejected,
        if s.shutdown { "; shutdown" } else { "" }
    );
}

/// `serve --listen`: the persistent solver daemon
/// (see `acetone::sched::serve::daemon` for the protocol).
fn serve_daemon_cmd(opts: &Opts) -> Result<()> {
    let listen = opts.get("listen").unwrap_or("-");
    let defaults = ServeDefaults::from_opts(opts)?;
    let cfg = PortfolioConfig {
        cache_dir: opts.get("cache-dir").map(PathBuf::from),
        cache_budget: opts.opt_parsed("cache-budget")?,
        ..PortfolioConfig::default()
    };
    let dcfg = DaemonConfig {
        max_inflight: opts.usize("max-inflight", 64)?,
        workers: opts.usize("workers", 0)?,
        ..DaemonConfig::default()
    };
    let mut daemon = Daemon::new(cfg, dcfg);
    if listen == "-" {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let summary = daemon.run_session(stdin.lock(), stdout.lock(), line_parser(&defaults))?;
        log_session(&summary);
        return Ok(());
    }
    listen_unix(&mut daemon, listen, &defaults)
}

/// Accept connections on a unix socket, one session at a time (the
/// daemon, its caches and its counters persist across connections). A
/// `shutdown` verb ends the whole daemon; a client EOF only ends its
/// session.
#[cfg(unix)]
fn listen_unix(daemon: &mut Daemon, path: &str, defaults: &ServeDefaults) -> Result<()> {
    use std::os::unix::net::UnixListener;
    // A leftover socket file from an unclean exit would fail the bind
    // with AddrInUse.
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path).with_context(|| format!("binding {path}"))?;
    eprintln!("serve: listening on {path} (JSONL requests; {{\"verb\":\"shutdown\"}} stops)");
    loop {
        let (stream, _) = listener.accept()?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let summary = daemon.run_session(reader, stream, line_parser(defaults))?;
        log_session(&summary);
        if summary.shutdown {
            break;
        }
    }
    let _ = std::fs::remove_file(path);
    Ok(())
}

#[cfg(not(unix))]
fn listen_unix(_daemon: &mut Daemon, _path: &str, _defaults: &ServeDefaults) -> Result<()> {
    bail!("--listen SOCKET needs a unix platform; use --listen - for stdio")
}

fn dag_cmd(opts: &Opts) -> Result<()> {
    let n = opts.usize("nodes", 20)?;
    let mut cfg = acetone::daggen::DagGenConfig::paper(n);
    cfg.density = opts.f64("density", 0.10)?;
    let g = acetone::daggen::generate(&cfg, opts.u64("seed", 1)?);
    println!("{}", g.to_dot());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scrape every flag name this file parses: any string literal fed to
    /// an `Opts`/`Json` accessor (`get`/`usize`/`u64`/`f64`/`opt_parsed`/
    /// `parsed`) names one. The serve JSONL keys deliberately reuse the
    /// flag names, so one scrape covers both surfaces.
    fn parsed_flags() -> std::collections::BTreeSet<String> {
        let src = include_str!("main.rs");
        let mut flags = std::collections::BTreeSet::new();
        for accessor in ["get", "usize", "u64", "f64", "opt_parsed", "parsed"] {
            let needle = format!(".{accessor}(\"");
            let mut rest = src;
            while let Some(at) = rest.find(&needle) {
                rest = &rest[at + needle.len()..];
                let end = rest.find('"').expect("unterminated flag literal");
                flags.insert(rest[..end].to_string());
            }
        }
        flags
    }

    #[test]
    fn help_covers_every_parsed_flag() {
        let flags = parsed_flags();
        // Scraper sanity: flags only recent PRs introduced must be seen.
        assert!(flags.contains("cache-dir"), "scraper missed serve flags: {flags:?}");
        assert!(flags.contains("node-limit"), "scraper missed budget flags: {flags:?}");
        assert!(flags.contains("listen"), "scraper missed daemon flags: {flags:?}");
        assert!(flags.contains("max-inflight"), "scraper missed daemon flags: {flags:?}");
        assert!(flags.contains("cache-budget"), "scraper missed daemon flags: {flags:?}");
        assert!(flags.contains("id"), "scraper missed the serve id key: {flags:?}");
        assert!(flags.contains("pipeline"), "scraper missed the pipeline flag: {flags:?}");
        assert!(flags.contains("mode"), "scraper missed the serve mode key: {flags:?}");
        for flag in &flags {
            assert!(
                HELP.contains(&format!("--{flag}")) || HELP.contains(&format!("\"{flag}\"")),
                "--{flag} is parsed but undocumented in HELP"
            );
        }
    }

    #[test]
    fn help_covers_every_subcommand() {
        // Keep in sync with the `dispatch` match — the help text must
        // name each arm.
        let subcommands =
            ["export-models", "schedule", "wcet", "simulate", "run", "codegen", "serve", "dag"];
        for cmd in subcommands {
            assert!(HELP.contains(cmd), "subcommand {cmd} missing from HELP");
        }
    }

    #[test]
    fn serve_stream_parses_defaults_and_overrides() {
        let args = ["--cores", "3", "--node-limit", "500", "--nogood-capacity", "64"]
            .map(String::from);
        let opts = Opts::parse(&args).unwrap();
        let text = "\n# comment\n{\"nodes\": 12, \"seed\": 2}\n\
                    {\"nodes\": 8, \"cores\": 2, \"node-limit\": 9, \"timeout\": 1, \
                     \"nogood-capacity\": 9}\n";
        let specs = parse_serve_stream(text, &opts).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].g.n(), 12);
        assert_eq!(specs[0].m, 3, "CLI default applies");
        assert_eq!(specs[0].budget.node_limit, Some(500));
        assert_eq!(specs[0].nogood_capacity, Some(64), "CLI default applies");
        assert_eq!(specs[1].m, 2, "per-line override wins");
        assert_eq!(specs[1].budget.node_limit, Some(9));
        assert_eq!(specs[1].budget.deadline, Some(Duration::from_secs(1)));
        assert_eq!(specs[1].nogood_capacity, Some(9), "per-line override wins");
    }

    #[test]
    fn serve_stream_parses_cp_global_flags() {
        // CLI default: disjunctive on for every line unless overridden.
        let args = ["--cp-disjunctive", "true"].map(String::from);
        let opts = Opts::parse(&args).unwrap();
        let text = "{\"nodes\": 8, \"seed\": 1}\n\
                    {\"nodes\": 8, \"seed\": 2, \"cp-disjunctive\": false, \
                     \"cp-binpacking\": true}\n\
                    {\"nodes\": 8, \"seed\": 3, \"cp-disjunctive\": false}\n";
        let specs = parse_serve_stream(text, &opts).unwrap();
        assert_eq!(
            specs[0].cp_globals,
            Some(CpGlobals { disjunctive: true, binpacking: false }),
            "CLI default applies"
        );
        assert_eq!(
            specs[1].cp_globals,
            Some(CpGlobals { disjunctive: false, binpacking: true }),
            "per-line override wins"
        );
        assert_eq!(specs[2].cp_globals, None, "both off collapses to the config default");

        let bad = "{\"nodes\": 8, \"cp-binpacking\": \"yes\"}\n";
        let err = parse_serve_stream(bad, &Opts::parse(&[]).unwrap()).unwrap_err().to_string();
        assert!(err.contains("cp-binpacking"), "boolean type error names the key: {err}");
    }

    #[test]
    fn serve_stream_parses_ids_and_rejects_duplicates() {
        let opts = Opts::parse(&[]).unwrap();
        let text = "{\"nodes\": 6, \"id\": \"job-a\"}\n\n{\"nodes\": 6}\n";
        let specs = parse_serve_stream(text, &opts).unwrap();
        assert_eq!(specs[0].id, "job-a");
        assert_eq!(specs[1].id, "line-3", "fallback id names the input line");
        assert!(!specs[0].cancelled);

        let dup = "{\"nodes\": 6, \"id\": \"a\"}\n{\"nodes\": 7, \"id\": \"a\"}\n";
        let err = parse_serve_stream(dup, &opts).unwrap_err().to_string();
        assert!(err.contains("duplicate id"), "got {err}");
        assert!(err.contains("line 2") && err.contains("line 1"), "both lines named: {err}");

        assert!(parse_serve_stream("{\"nodes\": 6, \"id\": 7}", &opts).is_err());

        let c = parse_serve_stream("{\"nodes\": 6, \"cancelled\": true}", &opts).unwrap();
        assert!(c[0].cancelled);
        assert!(parse_serve_stream("{\"nodes\": 6, \"cancelled\": 1}", &opts).is_err());
    }

    #[test]
    fn serve_stream_parses_platform_keys() {
        let opts = Opts::parse(&[]).unwrap();
        let text = "{\"nodes\": 6, \"cores\": 2, \"speeds\": [1.0, 0.5], \
                     \"core-classes\": [0, 1], \
                     \"comm-matrix\": [[1.0, 2.0], [2.0, 1.0]]}\n\
                    {\"nodes\": 6, \"cores\": 3, \"speeds\": [1.0, 1.0, 1.0]}\n\
                    {\"nodes\": 6, \"cores\": 2, \"core-classes\": [0, 1]}\n";
        let specs = parse_serve_stream(text, &opts).unwrap();
        let p = specs[0].platform.as_ref().expect("platform parsed");
        assert_eq!(p.speeds, vec![SPEED_SCALE, SPEED_SCALE / 2]);
        assert_eq!(p.core_classes, vec![0, 1]);
        assert_eq!(p.comm_factors[0][1], 2 * SPEED_SCALE);
        // All-nominal speeds still build a platform; resolution collapses
        // it to the platform-free encoding (cache.rs pins the key side).
        let q = specs[1].platform.as_ref().expect("uniform platform parsed");
        assert_eq!(q.speeds, vec![SPEED_SCALE; 3]);
        // Classes without a matrix default to a nominal k×k matrix.
        let r = specs[2].platform.as_ref().expect("classes-only platform parsed");
        assert_eq!(r.comm_factors, vec![vec![SPEED_SCALE; 2]; 2]);
        assert_eq!(specs.last().unwrap().platform.as_ref().map(|p| p.speeds.len()), Some(2));
        // No platform keys at all → no platform.
        let bare = parse_serve_stream("{\"nodes\": 6}", &opts).unwrap();
        assert!(bare[0].platform.is_none());
    }

    #[test]
    fn serve_stream_parses_pipeline_mode() {
        let opts = Opts::parse(&[]).unwrap();
        let text = "{\"nodes\": 6, \"mode\": \"pipeline\", \"stream-depth\": 4}\n\
                    {\"nodes\": 6, \"mode\": \"solve\"}\n\
                    {\"nodes\": 6}\n";
        let specs = parse_serve_stream(text, &opts).unwrap();
        assert!(specs[0].pipeline);
        assert_eq!(specs[0].stream_depth, Some(4));
        assert!(!specs[1].pipeline, "explicit one-shot mode");
        assert!(!specs[2].pipeline && specs[2].stream_depth.is_none(), "one-shot default");
        // Unknown modes and non-string modes error with the line number.
        assert!(parse_serve_stream("{\"nodes\": 6, \"mode\": \"stream\"}", &opts).is_err());
        assert!(parse_serve_stream("{\"nodes\": 6, \"mode\": 3}", &opts).is_err());
        assert!(parse_serve_stream("{\"nodes\": 6, \"stream-depth\": -2}", &opts).is_err());
    }

    #[test]
    fn serve_stream_rejects_malformed_platforms() {
        let opts = Opts::parse(&[]).unwrap();
        let fails = [
            // non-positive and non-numeric speeds
            "{\"nodes\": 5, \"cores\": 2, \"speeds\": [1.0, 0.0]}",
            "{\"nodes\": 5, \"cores\": 2, \"speeds\": [1.0, -2.0]}",
            "{\"nodes\": 5, \"cores\": 2, \"speeds\": [1.0, \"fast\"]}",
            // wrong lengths
            "{\"nodes\": 5, \"cores\": 2, \"speeds\": [1.0]}",
            "{\"nodes\": 5, \"cores\": 2, \"core-classes\": [0]}",
            "{\"nodes\": 5, \"cores\": 2, \"core-classes\": [0, -1]}",
            // ragged / malformed matrix
            "{\"nodes\": 5, \"cores\": 2, \"core-classes\": [0, 1], \
              \"comm-matrix\": [[1.0, 1.0], [1.0]]}",
            "{\"nodes\": 5, \"cores\": 2, \"comm-matrix\": [1.0, 1.0]}",
            // class out of the matrix's range
            "{\"nodes\": 5, \"cores\": 2, \"core-classes\": [0, 3], \
              \"comm-matrix\": [[1.0]]}",
        ];
        for line in fails {
            let err = parse_serve_stream(line, &opts).unwrap_err().to_string();
            assert!(err.contains("line 1"), "{line}: error must carry the line number: {err}");
        }
    }

    #[test]
    fn serve_stream_rejects_garbage() {
        let opts = Opts::parse(&[]).unwrap();
        assert!(parse_serve_stream("{\"cores\": 2}", &opts).is_err(), "no problem given");
        assert!(parse_serve_stream("not json", &opts).is_err());
        // Degenerate problems error with the line number instead of
        // tripping the portfolio's asserts mid-batch.
        assert!(parse_serve_stream("{\"nodes\": 5, \"cores\": 0}", &opts).is_err());
        assert!(parse_serve_stream("{\"nodes\": 5, \"cores\": -3}", &opts).is_err());
        assert!(parse_serve_stream("{\"nodes\": 0}", &opts).is_err());
        // Fractional or negative budgets hard-error rather than silently
        // truncating to an expired deadline / zero-node budget.
        assert!(parse_serve_stream("{\"nodes\": 5, \"timeout\": 0.5}", &opts).is_err());
        assert!(parse_serve_stream("{\"nodes\": 5, \"node-limit\": -5}", &opts).is_err());
        // The learning knob follows the same non-negative-integer rule.
        assert!(parse_serve_stream("{\"nodes\": 5, \"nogood-capacity\": -1}", &opts).is_err());
        assert!(parse_serve_stream("{\"nodes\": 5, \"nogood-capacity\": 0.5}", &opts).is_err());
    }
}
