//! Random DAG generator for the §4 evaluation.
//!
//! Follows the paper's three-step process exactly:
//! 1. node instantiation with unique indices;
//! 2. edge creation connecting lower-indexed to higher-indexed nodes (which
//!    guarantees acyclicity) until the requested density (Eq. 14) is met;
//! 3. a verification/repair step ensuring a single sink node (§2.2).
//!
//! Node WCETs and edge latencies are sampled uniformly from `[1, 10]`
//! (inclusive), as in §4.1. Generation is fully deterministic given a seed.

use crate::graph::{ensure_single_sink, Cycles, Dag};
use crate::util::rng::SplitMix64;

/// Parameters of the random-DAG workload generator (§4.1 defaults).
#[derive(Debug, Clone)]
pub struct DagGenConfig {
    /// Number of nodes before the single-sink repair step.
    pub nodes: usize,
    /// Target density per Eq. (14): `|E| / (|V|(|V|−1)/2)`. Paper: 0.10.
    pub density: f64,
    /// WCET range (inclusive). Paper: `[1, 10]`.
    pub wcet_range: (Cycles, Cycles),
    /// Edge-latency range (inclusive). Paper: `[1, 10]`.
    pub comm_range: (Cycles, Cycles),
    /// Guarantee weak connectivity (every non-first node gets ≥1 parent).
    /// The paper's graphs are "moderately connected"; disconnected floating
    /// nodes would make speedup trivially linear, so we default to true.
    pub connected: bool,
}

impl DagGenConfig {
    /// The paper's §4.1 setup for a given node count.
    pub fn paper(nodes: usize) -> Self {
        Self {
            nodes,
            density: 0.10,
            wcet_range: (1, 10),
            comm_range: (1, 10),
            connected: true,
        }
    }
}

/// Generate one random single-sink DAG.
pub fn generate(cfg: &DagGenConfig, seed: u64) -> Dag {
    assert!(cfg.nodes >= 2, "need at least 2 nodes");
    assert!((0.0..=1.0).contains(&cfg.density), "density in [0,1]");
    let mut rng = SplitMix64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xACE7_0E);
    let mut g = Dag::new();

    // Step 1: nodes with unique indices.
    for i in 0..cfg.nodes {
        let t = rng.range(cfg.wcet_range.0, cfg.wcet_range.1);
        g.add_node(format!("v{i}"), t);
    }

    // Step 2: edges low-index → high-index until the density target.
    let max_edges = cfg.nodes * (cfg.nodes - 1) / 2;
    let target = ((cfg.density * max_edges as f64).round() as usize).max(cfg.nodes - 1);
    let mut present = vec![false; cfg.nodes * cfg.nodes];
    let mut count = 0;
    if cfg.connected {
        // Give every node (except node 0) one parent first: a random tree.
        for v in 1..cfg.nodes {
            let u = rng.next_below(v as u64) as usize;
            let w = rng.range(cfg.comm_range.0, cfg.comm_range.1);
            g.add_edge(u, v, w);
            present[u * cfg.nodes + v] = true;
            count += 1;
        }
    }
    while count < target.min(max_edges) {
        let u = rng.next_below((cfg.nodes - 1) as u64) as usize;
        let v = u + 1 + rng.next_below((cfg.nodes - u - 1) as u64) as usize;
        if present[u * cfg.nodes + v] {
            continue;
        }
        let w = rng.range(cfg.comm_range.0, cfg.comm_range.1);
        g.add_edge(u, v, w);
        present[u * cfg.nodes + v] = true;
        count += 1;
    }

    // Step 3: single-sink verification/repair.
    ensure_single_sink(&mut g);
    debug_assert!(g.is_acyclic());
    g
}

/// Generate the `count`-graph test set used by Figs. 7–8 for one node size.
pub fn generate_set(cfg: &DagGenConfig, base_seed: u64, count: usize) -> Vec<Dag> {
    (0..count)
        .map(|i| generate(cfg, base_seed.wrapping_add(i as u64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let cfg = DagGenConfig::paper(20);
        let a = generate(&cfg, 42);
        let b = generate(&cfg, 42);
        assert_eq!(a.n(), b.n());
        assert_eq!(a.edges().collect::<Vec<_>>(), b.edges().collect::<Vec<_>>());
        let c = generate(&cfg, 43);
        assert!(
            a.edges().collect::<Vec<_>>() != c.edges().collect::<Vec<_>>(),
            "different seeds should differ"
        );
    }

    #[test]
    fn respects_density_and_single_sink() {
        for n in [20, 50, 100] {
            let cfg = DagGenConfig::paper(n);
            let g = generate(&cfg, 7);
            assert!(g.single_sink().is_some());
            assert!(g.is_acyclic());
            // density measured on the pre-repair node count; allow slack for
            // the connectivity floor and the virtual sink.
            let measured = g.density();
            assert!(
                (0.04..=0.25).contains(&measured),
                "density {measured} out of band for n={n}"
            );
        }
    }

    #[test]
    fn weights_in_range() {
        let cfg = DagGenConfig::paper(50);
        let g = generate(&cfg, 1);
        for v in 0..g.n() {
            if g.name(v) != "__sink__" {
                let t = g.wcet(v);
                assert!((1..=10).contains(&t), "wcet {t}");
            }
        }
        for (u, v, w) in g.edges() {
            if g.name(v) != "__sink__" {
                assert!((1..=10).contains(&w), "edge {u}->{v} w={w}");
            }
        }
    }

    #[test]
    fn connected_mode_gives_every_node_a_parent() {
        let cfg = DagGenConfig::paper(30);
        let g = generate(&cfg, 3);
        let sources = g.sources();
        assert_eq!(sources, vec![0], "only node 0 may be a source");
    }

    #[test]
    fn set_generation_counts() {
        let cfg = DagGenConfig::paper(20);
        let set = generate_set(&cfg, 100, 5);
        assert_eq!(set.len(), 5);
    }
}
