//! Static WCET analysis (the OTAWA analogue of §5.4).
//!
//! OTAWA derives per-layer worst-case cycle bounds from the compiled binary
//! for a simple in-order ARM target (lpc2138). We replace it with a
//! loop-nest cost model: every operator's generated C code is a fixed loop
//! nest whose trip counts are known from the shapes, so its WCET is a
//! polynomial in the shapes with per-operation cycle constants. Constants
//! are calibrated against the paper's Table 1 magnitudes (≈50 cycles/MAC
//! class machine, no cache); see `figures table1` for the side-by-side.
//!
//! The module also provides:
//! * the communication-operator WCET of Table 2 (`comm_wcet`);
//! * the §5.4 global-WCET composition over a schedule (`compose_global`):
//!   per-core accumulation with cross-core synchronization barriers taking
//!   the maximum accumulated WCET. This is the *optimistic* composition —
//!   a Writing operator is assumed never to wait for the reader — which is
//!   exactly why the paper's predicted 46 % segment gain shrinks to a
//!   measured 31 % (§5.5 Observation 3); the full-protocol behaviour lives
//!   in `crate::sim`.

use crate::graph::{Cycles, Dag};
use crate::nn::{numel, Network, Op};
use crate::sched::{derive_programs, CoreStep, Platform, Schedule, SPEED_SCALE};
use std::collections::HashMap;

/// Per-operation cycle constants of the target (§2.1's homogeneous UMA
/// cores; defaults calibrated to the paper's OTAWA Table 1 magnitudes).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Multiply-accumulate incl. operand loads (conv / dense inner loop).
    pub cycles_per_mac: f64,
    /// Compare-and-select incl. load (pooling inner loop).
    pub cycles_per_cmp: f64,
    /// Element copy (Input/Output/Split/Concat loops).
    pub cycles_per_copy: f64,
    /// Shared-memory copy per element in a Writing/Reading operator.
    pub cycles_per_comm_elem: f64,
    /// Flag handshake + loop setup of a Writing/Reading operator.
    pub comm_setup: Cycles,
    /// §2.1: multi-core interference margin added to every bound
    /// (e.g. 0.10 = +10 %). Zero for single-core analysis.
    pub interference_margin: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            cycles_per_mac: 50.0,
            cycles_per_cmp: 40.0,
            cycles_per_copy: 35.0,
            cycles_per_comm_elem: 1.5,
            comm_setup: 2_000,
            interference_margin: 0.0,
        }
    }
}

impl CostModel {
    fn margin(&self, cycles: f64) -> Cycles {
        (cycles * (1.0 + self.interference_margin)).round() as Cycles
    }

    /// WCET bound of one operator instance (Table 1 analogue).
    pub fn layer_wcet(&self, op: &Op, input_shapes: &[Vec<usize>], out_shape: &[usize]) -> Cycles {
        let out_elems = numel(out_shape) as f64;
        let raw = match op {
            // Input/Output: one copy loop over the tensor (Alg. 1 ll. 3-4).
            Op::Input { .. } | Op::Output => out_elems * self.cycles_per_copy,
            Op::Split => out_elems * self.cycles_per_copy,
            Op::Concat => out_elems * self.cycles_per_copy,
            // Reshape "does not modify anything, leading to a zero WCET".
            Op::Reshape { .. } => 0.0,
            Op::Conv2D { kh, kw, .. } => {
                let cin = input_shapes[0][2] as f64;
                out_elems * (*kh as f64) * (*kw as f64) * cin * self.cycles_per_mac
            }
            Op::MaxPool { k, .. } | Op::AvgPool { k, .. } => {
                out_elems * (*k as f64) * (*k as f64) * self.cycles_per_cmp
            }
            Op::Dense { units, .. } => {
                let inn = input_shapes[0][0] as f64;
                inn * (*units as f64) * self.cycles_per_mac
            }
        };
        self.margin(raw)
    }

    /// WCET bound of the data-handling part of one Writing or Reading
    /// operator (Table 2 analogue): flag handshake + element copy loop.
    /// Writing and Reading share the code shape, hence one bound (§5.4).
    pub fn comm_wcet(&self, bytes: usize) -> Cycles {
        self.comm_setup + self.margin(bytes as f64 / 4.0 * self.cycles_per_comm_elem)
    }
}

/// The per-layer WCET table of a network (Table 1).
pub fn layer_table(net: &Network, cm: &CostModel) -> Vec<(String, Cycles)> {
    let shapes = net.shapes();
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let ins = net.input_shapes(i, &shapes);
            (l.name.clone(), cm.layer_wcet(&l.op, &ins, &shapes[i]))
        })
        .collect()
}

/// Per-(layer, core-class) WCET table — the heterogeneous Table 1.
/// Class `k`'s bound is the base layer WCET scaled by
/// `SPEED_SCALE / class_speeds[k]`, rounding up: the same fixed-point rule
/// [`ResolvedPlatform`](crate::sched::ResolvedPlatform) applies to plain
/// node weights, computed here once per layer so a
/// [`Platform::cost_table`] carries analysis-grade per-class bounds.
pub fn layer_table_classes(
    net: &Network,
    cm: &CostModel,
    class_speeds: &[u32],
) -> Vec<Vec<Cycles>> {
    assert!(class_speeds.iter().all(|&s| s > 0), "class speeds must be positive");
    layer_table(net, cm)
        .into_iter()
        .map(|(_, w)| {
            class_speeds
                .iter()
                .map(|&s| {
                    (((w as u128) * SPEED_SCALE as u128 + s as u128 - 1) / s as u128) as Cycles
                })
                .collect()
        })
        .collect()
}

/// A ready-to-attach heterogeneous [`Platform`] for a network: cores map
/// to speed classes (`core_classes[c] < class_speeds.len()`),
/// communication stays nominal, and the cost table carries the
/// per-(layer, class) bounds of [`layer_table_classes`] — so a
/// platform-aware solve prices every layer with its analysis-grade bound
/// instead of runtime-scaling one number.
pub fn heterogeneous_platform(
    net: &Network,
    cm: &CostModel,
    core_classes: Vec<usize>,
    class_speeds: &[u32],
) -> Platform {
    let k = class_speeds.len();
    let speeds = core_classes.iter().map(|&c| class_speeds[c]).collect();
    Platform {
        speeds,
        core_classes,
        comm_factors: vec![vec![SPEED_SCALE; k]; k],
        cost_table: Some(layer_table_classes(net, cm, class_speeds)),
    }
}

/// Result of the §5.4 global-WCET composition.
#[derive(Debug, Clone)]
pub struct ComposedWcet {
    /// Global bound: max accumulated WCET over all cores at the end.
    pub makespan: Cycles,
    /// Per-core final accumulated WCET.
    pub per_core: Vec<Cycles>,
    /// Completion bound per node (first instance to finish).
    pub node_finish: HashMap<usize, Cycles>,
}

/// Compose the global WCET of a schedule layer-by-layer (§5.4): each core
/// accumulates its layers' WCETs in program order; a Reading operator
/// synchronizes on the matching Writing operator's completion (barrier =
/// max of accumulated WCETs); Writing operators never block (optimistic —
/// the single-buffer back-pressure of §5.2 is modelled in `crate::sim`).
///
/// `comm_bytes(src_node)` gives the payload size of a transfer, so the
/// caller chooses between Table-2-style sizes (networks) or `w(e)`-derived
/// sizes (random DAGs).
pub fn compose_global(
    g: &Dag,
    schedule: &Schedule,
    cm: &CostModel,
    comm_bytes: &dyn Fn(usize) -> usize,
) -> ComposedWcet {
    let programs = derive_programs(g, schedule);
    let m = programs.len();
    let mut clock = vec![0u64; m];
    let mut pc = vec![0usize; m];
    // Write completion bound per (channel, seq).
    let mut written: HashMap<(usize, usize, usize), Cycles> = HashMap::new();
    let mut node_finish: HashMap<usize, Cycles> = HashMap::new();
    loop {
        let mut progress = false;
        let mut blocked = false;
        for c in 0..m {
            while pc[c] < programs[c].steps.len() {
                match &programs[c].steps[pc[c]] {
                    CoreStep::Compute { node, .. } => {
                        clock[c] += g.wcet(*node);
                        let e = node_finish.entry(*node).or_insert(clock[c]);
                        *e = (*e).min(clock[c]);
                        pc[c] += 1;
                        progress = true;
                    }
                    CoreStep::Write { comm } => {
                        clock[c] += cm.comm_wcet(comm_bytes(comm.src));
                        written.insert((comm.src_core, comm.dst_core, comm.seq), clock[c]);
                        pc[c] += 1;
                        progress = true;
                    }
                    CoreStep::Read { comm } => {
                        let key = (comm.src_core, comm.dst_core, comm.seq);
                        match written.get(&key) {
                            Some(&t) => {
                                // Barrier: adopt the max accumulated WCET,
                                // then pay the Reading operator itself.
                                clock[c] = clock[c].max(t)
                                    + cm.comm_wcet(comm_bytes(comm.src));
                                pc[c] += 1;
                                progress = true;
                            }
                            None => {
                                blocked = true;
                                break; // writer hasn't run yet: try later
                            }
                        }
                    }
                }
            }
        }
        if pc.iter().zip(&programs).all(|(&p, prog)| p == prog.steps.len()) {
            break;
        }
        if !progress {
            assert!(blocked, "compose_global: inconsistent state");
            panic!("compose_global: deadlock — schedule-derived programs are cyclic");
        }
    }
    ComposedWcet { makespan: clock.iter().copied().max().unwrap_or(0), per_core: clock, node_finish }
}

/// Serial (single-core) global WCET: plain sum, no communication.
pub fn serial_global(g: &Dag) -> Cycles {
    g.total_wcet()
}

#[cfg(test)]
// These tests pin the deprecated legacy entry points byte-identically
// until the parity suites retire them.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::nn::zoo::{googlenet, Scale};
    use crate::nn::Padding;
    use crate::sched::dsh::Dsh;
    use crate::sched::Scheduler;

    #[test]
    fn reshape_is_free() {
        let cm = CostModel::default();
        assert_eq!(cm.layer_wcet(&Op::Reshape { shape: vec![10] }, &[vec![10]], &[10]), 0);
    }

    #[test]
    fn conv_dominates_pool() {
        let cm = CostModel::default();
        let conv = cm.layer_wcet(
            &Op::Conv2D { out_ch: 64, kh: 7, kw: 7, stride: 2, padding: Padding::Same, relu: true },
            &[vec![224, 224, 3]],
            &[112, 112, 64],
        );
        let pool = cm.layer_wcet(
            &Op::MaxPool { k: 3, stride: 2, padding: Padding::Same },
            &[vec![112, 112, 64]],
            &[56, 56, 64],
        );
        assert!(conv > 10 * pool);
    }

    #[test]
    fn table1_magnitudes() {
        // Calibration sanity: conv_1 and conv_2 of the paper-scale
        // GoogLeNet must land within ~3× of Table 1's OTAWA bounds
        // (8.16e9 and 1.59e10 cycles) and preserve conv_2 > conv_1.
        let net = googlenet(Scale::Paper);
        let table = layer_table(&net, &CostModel::default());
        let get = |n: &str| table.iter().find(|(name, _)| name == n).unwrap().1;
        let c1 = get("conv_1") as f64;
        let c2 = get("conv_2") as f64;
        assert!(c2 > c1);
        assert!((2.7e9..2.5e10).contains(&c1), "conv_1 = {c1:e}");
        assert!((5.3e9..4.8e10).contains(&c2), "conv_2 = {c2:e}");
        assert_eq!(get("reshape"), 0);
        // Total should be within the same order as the paper's 2.90e10.
        let total: u64 = table.iter().map(|&(_, t)| t).sum();
        assert!((1.0e10..9.0e10).contains(&(total as f64)), "total {total:e}");
    }

    #[test]
    fn interference_margin_scales_bounds() {
        let mut cm = CostModel::default();
        let base = cm.layer_wcet(&Op::Split, &[vec![100]], &[100]);
        cm.interference_margin = 0.10;
        let with = cm.layer_wcet(&Op::Split, &[vec![100]], &[100]);
        assert_eq!(with, (base as f64 * 1.10).round() as u64);
    }

    #[test]
    fn per_class_layer_table_feeds_a_platform() {
        use crate::nn::zoo::lenet5;
        use crate::sched::ResolvedPlatform;
        let net = lenet5(Scale::Tiny);
        let cm = CostModel::default();
        let base = layer_table(&net, &cm);
        // Class 0 nominal, class 1 at half speed: every bound doubles.
        let table = layer_table_classes(&net, &cm, &[SPEED_SCALE, SPEED_SCALE / 2]);
        assert_eq!(table.len(), base.len());
        for (v, (_, w)) in base.iter().enumerate() {
            assert_eq!(table[v], vec![*w, 2 * *w]);
        }
        // The ready-made platform resolves and prices layers per class.
        let p = heterogeneous_platform(&net, &cm, vec![0, 1], &[SPEED_SCALE, SPEED_SCALE / 2]);
        assert!(p.validate(2).is_ok());
        let g = net.to_dag(&cm);
        let plat = ResolvedPlatform::resolve(Some(&p), &g, 2);
        assert!(!plat.is_uniform());
        for v in 0..g.n() {
            assert_eq!(plat.cost(v, 0), g.wcet(v), "layer {v} nominal on the fast core");
            assert_eq!(plat.cost(v, 1), 2 * g.wcet(v), "layer {v} doubled on the slow core");
        }
    }

    #[test]
    fn compose_serial_equals_total() {
        let g = crate::graph::paper_example_dag();
        let mut s = Schedule::new(1);
        let mut t = 0;
        for v in g.topo_order() {
            s.place(&g, v, 0, t);
            t += g.wcet(v);
        }
        let cm = CostModel { comm_setup: 0, ..CostModel::default() };
        let out = compose_global(&g, &s, &cm, &|_| 0);
        assert_eq!(out.makespan, g.total_wcet());
    }

    #[test]
    fn compose_parallel_beats_serial_on_googlenet() {
        // The §5.4 experiment in miniature: schedule the Fig. 10 network on
        // 4 cores with DSH and compose; the parallel bound must be below
        // the serial sum (the paper reports an 8 % gain).
        let net = googlenet(Scale::Paper);
        let cm = CostModel::default();
        let g = net.to_dag(&cm);
        let sched = Dsh.schedule(&g, 4).schedule;
        let shapes = net.shapes();
        let bytes = move |v: usize| numel(&shapes[v]) * 4;
        let out = compose_global(&g, &sched, &cm, &bytes);
        let serial = serial_global(&g);
        assert!(
            out.makespan < serial,
            "parallel {} !< serial {}",
            out.makespan,
            serial
        );
        // Gain should be modest (conv_1/conv_2 dominate), under ~35 %.
        let gain = 1.0 - out.makespan as f64 / serial as f64;
        assert!((0.01..0.40).contains(&gain), "gain {gain}");
    }
}
