//! Compare every solver in the crate on the same workloads: the paper's
//! worked example (Fig. 3) and a §4.1 random graph — makespan, optimality,
//! duplicates and solve time side by side.
//!
//! Run: `cargo run --release --example scheduler_comparison`

use acetone::daggen::{generate, DagGenConfig};
use acetone::graph::{ensure_single_sink, paper_example_dag};
use acetone::metrics::Table;
use acetone::sched::bnb::ChouChung;
use acetone::sched::cp::{CpConfig, CpSolver, Encoding};
use acetone::sched::dsh::Dsh;
use acetone::sched::hlfet::Hlfet;
use acetone::sched::hybrid::Hybrid;
use acetone::sched::ish::Ish;
use acetone::sched::portfolio::{Portfolio, PortfolioConfig};
use acetone::sched::{check_valid, Scheduler};
use std::time::Duration;

fn main() {
    let mut fig3 = paper_example_dag();
    ensure_single_sink(&mut fig3);
    let mut rand20 = generate(&DagGenConfig::paper(20), 7);
    ensure_single_sink(&mut rand20);

    for (name, g, m) in [("Fig. 3 example", &fig3, 2), ("random n=20 (§4.1)", &rand20, 4)] {
        println!("\n### {name} on {m} cores (total WCET {} cycles)\n", g.total_wcet());
        let solvers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(Hlfet),
            Box::new(Ish),
            Box::new(Dsh),
            Box::new(ChouChung { timeout: Duration::from_secs(10), ..Default::default() }),
            Box::new(CpSolver::new(CpConfig::improved(Duration::from_secs(10)))),
            Box::new(CpSolver::new(CpConfig::tang(Duration::from_secs(10)))),
            Box::new(Hybrid { cp_timeout: Duration::from_secs(5), cp_node_limit: None }),
            Box::new(Portfolio::new(PortfolioConfig {
                exact_timeout: Duration::from_secs(10),
                ..Default::default()
            })),
        ];
        let mut t = Table::new(&["solver", "makespan", "speedup", "dups", "optimal", "time", "explored"]);
        for s in solvers {
            let r = s.schedule(g, m);
            check_valid(g, &r.schedule).expect("valid");
            t.row(vec![
                s.name().into(),
                r.schedule.makespan().to_string(),
                format!("{:.3}", r.schedule.speedup(g)),
                r.schedule.duplication_count().to_string(),
                r.optimal.to_string(),
                format!("{:?}", r.solve_time),
                r.explored.to_string(),
            ]);
        }
        println!("{}", t.markdown());
    }
}
