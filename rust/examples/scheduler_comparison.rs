//! Compare every solver in the crate on the same workloads: the paper's
//! worked example (Fig. 3) and a §4.1 random graph — makespan, optimality,
//! duplicates and solve time side by side.
//!
//! Run: `cargo run --release --example scheduler_comparison`

use acetone::daggen::{generate, DagGenConfig};
use acetone::graph::{ensure_single_sink, paper_example_dag};
use acetone::metrics::Table;
use acetone::sched::bnb::ChouChung;
use acetone::sched::cp::CpSolver;
use acetone::sched::dsh::Dsh;
use acetone::sched::hlfet::Hlfet;
use acetone::sched::hybrid::Hybrid;
use acetone::sched::ish::Ish;
use acetone::sched::portfolio::Portfolio;
use acetone::sched::{check_valid, Scheduler, SolveRequest};
use std::time::Duration;

fn main() {
    let mut fig3 = paper_example_dag();
    ensure_single_sink(&mut fig3);
    let mut rand20 = generate(&DagGenConfig::paper(20), 7);
    ensure_single_sink(&mut rand20);

    for (name, g, m) in [("Fig. 3 example", &fig3, 2), ("random n=20 (§4.1)", &rand20, 4)] {
        println!("\n### {name} on {m} cores (total WCET {} cycles)\n", g.total_wcet());
        // One budgeted request drives every solver — the unified API.
        let req = SolveRequest::new(g, m).deadline(Duration::from_secs(10));
        let solvers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(Hlfet),
            Box::new(Ish),
            Box::new(Dsh),
            Box::new(ChouChung::default()),
            Box::new(CpSolver::improved()),
            Box::new(CpSolver::tang()),
            Box::new(Hybrid),
            Box::new(Portfolio::default()),
        ];
        let mut t = Table::new(&["solver", "makespan", "speedup", "dups", "verdict", "time", "explored"]);
        for s in solvers {
            let r = s.solve(&req);
            check_valid(g, &r.schedule).expect("valid");
            t.row(vec![
                s.name().into(),
                r.schedule.makespan().to_string(),
                format!("{:.3}", r.schedule.speedup(g)),
                r.schedule.duplication_count().to_string(),
                format!("{:?}", r.termination),
                format!("{:?}", r.stats.wall),
                r.stats.explored.to_string(),
            ]);
        }
        println!("{}", t.markdown());
    }
}
