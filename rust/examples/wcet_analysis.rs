//! Static WCET analysis (the §5.4 experiment): per-layer bounds for the
//! Fig. 10 GoogLeNet at paper scale, the DSH schedule on four cores, and
//! the composed global WCET vs the sequential bound.
//!
//! Run: `cargo run --release --example wcet_analysis`

use acetone::metrics::{sci, Table};
use acetone::nn::{numel, zoo};
use acetone::sched::dsh::Dsh;
use acetone::sched::{Scheduler, SolveRequest};
use acetone::wcet::{compose_global, layer_table, serial_global, CostModel};

fn main() {
    let net = zoo::googlenet(zoo::Scale::Paper);
    let cm = CostModel::default();

    // Table-1-style per-layer bounds.
    let mut t = Table::new(&["Layer Name", "WCET [cycles]"]);
    let table = layer_table(&net, &cm);
    for (name, cycles) in &table {
        t.row(vec![name.clone(), sci(*cycles as f64)]);
    }
    let total: u64 = table.iter().map(|&(_, c)| c).sum();
    t.row(vec!["Total Sum".into(), sci(total as f64)]);
    println!("{}", t.markdown());

    // Schedule + compose on 1, 2, 4, 8 cores.
    let g = net.to_dag(&cm);
    let shapes = net.shapes();
    let serial = serial_global(&g);
    println!("sequential WCET: {}", sci(serial as f64));
    for m in [2usize, 4, 8] {
        let sched = Dsh.solve(&SolveRequest::new(&g, m)).schedule;
        let shapes = shapes.clone();
        let bytes = move |v: usize| numel(&shapes[v]) * 4;
        let composed = compose_global(&g, &sched, &cm, &bytes);
        println!(
            "{m} cores: parallel WCET {} ({:.1}% gain, {} duplicates)",
            sci(composed.makespan as f64),
            100.0 * (1.0 - composed.makespan as f64 / serial as f64),
            sched.duplication_count(),
        );
    }
    println!(
        "\nAs in the paper, the overall gain is modest — conv_1/conv_2 are \
         sequential and dominate — while the inception segment parallelizes well."
    );
}
