//! Quickstart: build a model, schedule it on multiple cores, inspect the
//! schedule, and statically bound the parallel WCET.
//!
//! Run: `cargo run --release --example quickstart`

use acetone::nn::{numel, zoo};
use acetone::sched::dsh::Dsh;
use acetone::sched::{check_valid, Scheduler, SolveRequest};
use acetone::wcet::{compose_global, serial_global, CostModel};

fn main() {
    // 1. A model from the zoo — the split LeNet-5 of the paper's Fig. 2.
    let net = zoo::lenet5_split(zoo::Scale::Tiny);
    println!("model: {} ({} layers, {} parameters)", net.name, net.layers.len(), net.param_count());

    // 2. Lower it to the §2.2 task DAG with the OTAWA-analogue cost model.
    let cm = CostModel::default();
    let g = net.to_dag(&cm);
    println!("task DAG: {} nodes, {} edges, width {}", g.n(), g.edge_count(), g.width());

    // 3. Schedule on two cores with the Duplication Scheduling Heuristic:
    //    one SolveRequest in, one SolveReport (schedule + verdict + stats) out.
    let result = Dsh.solve(&SolveRequest::new(&g, 2));
    check_valid(&g, &result.schedule).expect("valid schedule");
    println!(
        "DSH on 2 cores: makespan {} cycles, speedup {:.2}×, {} duplicate(s), {:?} in {:?}",
        result.schedule.makespan(),
        result.schedule.speedup(&g),
        result.schedule.duplication_count(),
        result.termination,
        result.stats.wall,
    );

    // 4. Static global WCET of the parallel code (§5.4 composition).
    let shapes = net.shapes();
    let bytes = move |v: usize| numel(&shapes[v]) * 4;
    let composed = compose_global(&g, &result.schedule, &cm, &bytes);
    let serial = serial_global(&g);
    println!(
        "global WCET: serial {} → parallel {} ({:.1}% gain)",
        serial,
        composed.makespan,
        100.0 * (1.0 - composed.makespan as f64 / serial as f64)
    );
}
