//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (JAX/Pallas → HLO text, built by
//! `make artifacts`), schedules the Fig. 10 GoogLeNet on four virtual
//! cores with DSH, serves a batch of inference requests through the
//! parallel flag-protocol engine (one OS thread per core, PJRT per-layer
//! executables), verifies numerics against both the single-core artifact
//! and the pure-Rust oracle, and reports latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example parallel_inference`

use acetone::exec::{run_full, run_parallel};
use acetone::nn::eval::{eval, Tensor};
use acetone::nn::{numel, weights, zoo};
use acetone::runtime::Manifest;
use acetone::sched::portfolio::PortfolioConfig;
use acetone::sched::serve::{BatchRequest, BatchSolver};
use acetone::sched::SolveRequest;
use acetone::wcet::CostModel;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let net = zoo::googlenet(zoo::Scale::Tiny);
    let mm = manifest.models.get("googlenet").expect("googlenet artifacts");
    let g = net.to_dag(&CostModel::default());
    let m = 4;
    // The serving entry point: sched::serve batches client requests over
    // the deterministic parallel portfolio. Here four "clients" ask for
    // the deployed 4-core schedule and one asks for a 2-core fallback:
    // the duplicates are deduplicated by canonical key and answered in
    // input order. The node budget (not the wall clock) bounds the exact
    // stages, so the schedule is identical on every machine, and the
    // persistent cache directory makes a *rerun of this example* answer
    // straight from disk — exactly what a restarted server does once a
    // model is deployed.
    let server = BatchSolver::new(PortfolioConfig {
        cache_dir: Some("artifacts/schedule-cache".into()),
        ..PortfolioConfig::default()
    });
    let mut batch = BatchRequest::new().workers(4);
    for _client in 0..4 {
        batch = batch.push(SolveRequest::new(&g, m).node_limit(2_000));
    }
    batch = batch.push(SolveRequest::new(&g, 2).node_limit(2_000));
    let out = server.solve_batch(&batch);
    let sched = out.reports[0].report.schedule.clone();
    println!(
        "googlenet (tiny) on {m} virtual cores: schedule makespan {} cycles, {} comms, \
         verdict {:?} (request sources: {:?}; batch {:?}; cache {:?})",
        sched.makespan(),
        acetone::sched::derive_comms(&g, &sched).len(),
        out.reports[0].report.termination,
        out.reports.iter().map(|r| r.source.as_str()).collect::<Vec<_>>(),
        out.stats,
        server.portfolio().cache_stats(),
    );

    let shapes = net.shapes();

    // One-shot path (per-request compilation) for the per-layer report.
    let input0 = Tensor::new(
        shapes[0].clone(),
        weights::input_tensor(numel(&shapes[0]), mm.seed ^ 1000),
    );
    let t_oneshot = Instant::now();
    let (_, report) = run_parallel(&net, &sched, mm, "artifacts", &input0)?;
    println!(
        "one-shot run (includes per-request PJRT compilation): {:?} ({} steps)",
        t_oneshot.elapsed(),
        report.steps.len()
    );

    // Serving path: the persistent engine compiles once, then streams.
    let t_build = Instant::now();
    let engine = acetone::exec::Engine::new(&net, &sched, mm, "artifacts")?;
    println!("engine built (all artifacts compiled) in {:?}", t_build.elapsed());

    let batch = 32u64;
    let mut worst = 0f32;
    let t0 = Instant::now();
    for req in 0..batch {
        let input = Tensor::new(
            shapes[0].clone(),
            weights::input_tensor(numel(&shapes[0]), mm.seed ^ (1000 + req)),
        );
        let out = engine.infer(&input)?;
        // Verify against both references.
        let (full, _) = run_full(mm, "artifacts", &input)?;
        let oracle = eval(&net, &input, mm.seed);
        worst = worst.max(max_err(&out, &full)).max(max_err(&out, &oracle));
    }
    let elapsed = t0.elapsed();
    // The verification re-runs the full artifact per request; time the
    // serving loop alone for the throughput number.
    let t1 = Instant::now();
    for req in 0..batch {
        let input = Tensor::new(
            shapes[0].clone(),
            weights::input_tensor(numel(&shapes[0]), mm.seed ^ (2000 + req)),
        );
        let _ = engine.infer(&input)?;
    }
    let serve = t1.elapsed();
    println!(
        "batch of {batch}: mean latency {:?}, throughput {:.1} req/s (verification loop took {:?}), worst max|Δ| {worst:.2e}",
        serve / batch as u32,
        batch as f64 / serve.as_secs_f64(),
        elapsed,
    );
    assert!(worst < 1e-3, "numerics drifted");
    println!("numerics OK — all layers computed by PJRT artifacts + native memory ops");
    Ok(())
}

fn max_err(a: &Tensor, b: &Tensor) -> f32 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}
