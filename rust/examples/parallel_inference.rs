//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (JAX/Pallas → HLO text, built by
//! `make artifacts`), schedules the Fig. 10 GoogLeNet on four virtual
//! cores with DSH, serves a batch of inference requests through the
//! parallel flag-protocol engine (one OS thread per core, PJRT per-layer
//! executables), verifies numerics against both the single-core artifact
//! and the pure-Rust oracle, and reports latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example parallel_inference`

use acetone::exec::{run_full, run_parallel};
use acetone::nn::eval::{eval, Tensor};
use acetone::nn::{numel, weights, zoo};
use acetone::runtime::Manifest;
use acetone::sched::portfolio::Portfolio;
use acetone::sched::SolveRequest;
use acetone::wcet::CostModel;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let net = zoo::googlenet(zoo::Scale::Tiny);
    let mm = manifest.models.get("googlenet").expect("googlenet artifacts");
    let g = net.to_dag(&CostModel::default());
    let m = 4;
    // The serving entry point: the deterministic parallel portfolio,
    // driven through the unified request API. The request's node budget
    // (not the wall clock) bounds the exact stages, so the schedule is
    // identical on every machine; the second solve of the same request
    // below is answered from the cache — exactly what a server does per
    // request once a model is deployed.
    let portfolio = Portfolio::default();
    let req = SolveRequest::new(&g, m).node_limit(2_000);
    let first = portfolio.solve_request(&req);
    let sched = first.report.schedule;
    // A repeat request is normally a cache hit; a wall-clock-cut first
    // solve (e.g. a very slow debug run) is deliberately not cached, so
    // report rather than assert.
    let replay = portfolio.solve_request(&req);
    println!(
        "googlenet (tiny) on {m} virtual cores: schedule makespan {} cycles, {} comms, \
         verdict {:?} (repeat request from cache: {}, stats: {:?})",
        sched.makespan(),
        acetone::sched::derive_comms(&g, &sched).len(),
        first.report.termination,
        replay.from_cache,
        portfolio.cache_stats(),
    );

    let shapes = net.shapes();

    // One-shot path (per-request compilation) for the per-layer report.
    let input0 = Tensor::new(
        shapes[0].clone(),
        weights::input_tensor(numel(&shapes[0]), mm.seed ^ 1000),
    );
    let t_oneshot = Instant::now();
    let (_, report) = run_parallel(&net, &sched, mm, "artifacts", &input0)?;
    println!(
        "one-shot run (includes per-request PJRT compilation): {:?} ({} steps)",
        t_oneshot.elapsed(),
        report.steps.len()
    );

    // Serving path: the persistent engine compiles once, then streams.
    let t_build = Instant::now();
    let engine = acetone::exec::Engine::new(&net, &sched, mm, "artifacts")?;
    println!("engine built (all artifacts compiled) in {:?}", t_build.elapsed());

    let batch = 32u64;
    let mut worst = 0f32;
    let t0 = Instant::now();
    for req in 0..batch {
        let input = Tensor::new(
            shapes[0].clone(),
            weights::input_tensor(numel(&shapes[0]), mm.seed ^ (1000 + req)),
        );
        let out = engine.infer(&input)?;
        // Verify against both references.
        let (full, _) = run_full(mm, "artifacts", &input)?;
        let oracle = eval(&net, &input, mm.seed);
        worst = worst.max(max_err(&out, &full)).max(max_err(&out, &oracle));
    }
    let elapsed = t0.elapsed();
    // The verification re-runs the full artifact per request; time the
    // serving loop alone for the throughput number.
    let t1 = Instant::now();
    for req in 0..batch {
        let input = Tensor::new(
            shapes[0].clone(),
            weights::input_tensor(numel(&shapes[0]), mm.seed ^ (2000 + req)),
        );
        let _ = engine.infer(&input)?;
    }
    let serve = t1.elapsed();
    println!(
        "batch of {batch}: mean latency {:?}, throughput {:.1} req/s (verification loop took {:?}), worst max|Δ| {worst:.2e}",
        serve / batch as u32,
        batch as f64 / serve.as_secs_f64(),
        elapsed,
    );
    assert!(worst < 1e-3, "numerics drifted");
    println!("numerics OK — all layers computed by PJRT artifacts + native memory ops");
    Ok(())
}

fn max_err(a: &Tensor, b: &Tensor) -> f32 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}
