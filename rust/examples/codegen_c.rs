//! Generate the ACETONE-style parallel C project for the split LeNet-5 on
//! two cores (Algorithms 2–3), compile it with the system C compiler, run
//! it, and show its self-check — the paper's §5 contribution end to end.
//!
//! Run: `cargo run --release --example codegen_c`

use acetone::codegen::generate_project;
use acetone::nn::zoo::{lenet5_split, Scale};
use acetone::sched::dsh::Dsh;
use acetone::sched::{Scheduler, SolveRequest};
use acetone::wcet::CostModel;
use std::process::Command;

fn main() -> anyhow::Result<()> {
    let net = lenet5_split(Scale::Tiny);
    let g = net.to_dag(&CostModel::default());
    let sched = Dsh.solve(&SolveRequest::new(&g, 2)).schedule;
    let out = std::env::temp_dir().join("acetone_codegen_example");
    let _ = std::fs::remove_dir_all(&out);
    generate_project(&net, &sched, 42, &out)?;
    println!("generated C project at {}:", out.display());
    for entry in std::fs::read_dir(&out)? {
        println!("  {}", entry?.file_name().to_string_lossy());
    }
    // Show the synchronization part of core 0's inference function.
    let core0 = std::fs::read_to_string(out.join("inference_0.c"))?;
    let writing: Vec<&str> = core0
        .lines()
        .skip_while(|l| !l.contains("Writing layer"))
        .take(6)
        .collect();
    println!("\nWriting operator (Algorithm 2, ll. 12–19):\n{}", writing.join("\n"));

    println!("\ncompiling with `make` (cc -O2 -ffp-contract=off -pthread)...");
    let cc = Command::new("make").current_dir(&out).output()?;
    anyhow::ensure!(cc.status.success(), "cc failed: {}", String::from_utf8_lossy(&cc.stderr));
    let run = Command::new(out.join("inference")).output()?;
    print!("{}", String::from_utf8_lossy(&run.stdout));
    anyhow::ensure!(run.status.success(), "generated binary self-check failed");
    println!("parallel C inference matches the Rust oracle — certifiable-code path verified");
    Ok(())
}
